//! The invocation reply path: a per-worker ring of payload-carrying
//! **reply frames** flowing target → sender, with replies larger than one
//! frame streamed as a pipelined sequence of chunk frames.
//!
//! The paper's ifuncs are fire-and-forget; anything the injected function
//! computes stays on the target. This module is the missing half of an
//! *invocation* (§5): after the execution engine finishes ingress frame
//! `frame_seq` (the `frame_seq`-th frame delivered on the link, counting
//! executed **and** rejected frames), the worker writes one *or more*
//! reply frames into a leader-mapped reply region with one-sided puts —
//! the same mechanism data frames travel by, just pointed back at the
//! sender. Each reply frame occupies a fixed [`REPLY_FRAME_BYTES`] slot so
//! the reader can find reply frame `seq` without parsing the stream, and
//! carries a *variable* chunk of up to [`REPLY_INLINE_CAP`] bytes:
//!
//! ```text
//!  | payload      | REPLY_INLINE_CAP B  chunk bytes (first payload_len valid)
//!  | frame_seq    | 8 B  ingress frame this reply answers (1-based)
//!  | r0           | 8 B  final chunk: injected main's return value
//!  |              |      STATUS_MORE chunks: byte offset of this chunk
//!  | total_len    | 8 B  full reply payload bytes across the whole stream
//!  | payload_len  | 8 B  valid chunk bytes in THIS frame
//!  | status       | 8 B  1 ok · 2 rejected · 3 overflow · 4 more chunks follow
//!  | seq          | 8 B  reply frame sequence number, written last
//! ```
//!
//! `seq` is the arrival barrier: the fabric delivers the final word of a
//! put last (the trailer-signal property of §3.4), and the trailer put is
//! issued *after* the chunk put on the same in-order QP, so once the
//! reader observes `seq` in a slot, every other field — chunk included —
//! has landed. Slots are reused modulo [`REPLY_SLOTS`]; the writer runs a
//! seqlock protocol (zero the seq word, write chunk + trailer, publish the
//! new seq last), and because the full 64-bit seq is stored, a reader that
//! missed a slot detects the overwrite — before or mid-copy — instead of
//! misreading a later lap's chunk.
//!
//! ## Streamed replies (no inline cap)
//!
//! A reply payload larger than [`REPLY_INLINE_CAP`] is **chunked**, the
//! way sPIN streams packet-sized handler output: chunks 1..k-1 ship with
//! [`STATUS_MORE`] (their trailer carries the stream's `total_len` and the
//! chunk's byte offset in the `r0` word), and the final chunk carries the
//! real status and `r0`. Every chunk occupies the next reply seq slot, so
//! one k-chunk reply consumes k slots of the ring — the leader-side
//! [`ReplyCollector`] reassembles the stream in seq order with the seqlock
//! lap checks intact, and feeds a *collected-watermark* credit back to the
//! worker so the [`ReplyWriter`] never overwrites a slot the collector has
//! not consumed. Replies larger than the whole ring therefore stream
//! through it as a sliding window. The writer itself never blocks: chunks
//! it cannot place yet queue worker-side and drain on
//! [`ReplyWriter::pump`] as credit arrives — a worker is never wedged by a
//! leader that is slow to collect.
//!
//! [`STATUS_OVERFLOW`] remains as a wire-compat status for a worker
//! configured with streaming disabled (`ClusterConfig::stream_replies:
//! false`): the frame ships an empty payload with `r0` intact (for
//! `db_get` that is the old r0-as-length behavior) and `total_len` set to
//! the size the caller missed.
//!
//! Both transports share this channel. Barrier/consumed credit is **not**
//! derived from reply seqs (a k-chunk reply advances them by k): the
//! worker advances a dedicated per-ingress-frame counter instead
//! ([`super::transport::ConsumedCounter`]).

use std::collections::{BTreeSet, HashMap, VecDeque};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::fabric::{MemPerm, MemoryRegion, RKey};
use crate::ucp::{Context, Endpoint};
use crate::{Error, Result};

use super::transport::PutSink;

/// Frames in a reply ring. Streamed replies are consumed promptly (the
/// [`ReplyCollector`] reads reply frames strictly in seq order and every
/// send/collect drives it), and the writer-side credit gate keeps chunk
/// `seq` within `REPLY_SLOTS` of the collector's watermark, so slots are
/// recycled without ever lapping an unread frame.
pub const REPLY_SLOTS: usize = 64;
/// Largest payload one reply frame carries inline (64 KiB). This is a
/// *chunk size*, not a reply-size cap: bigger payloads stream as multiple
/// chunk frames. Only a worker with `stream_replies: false` still reports
/// [`STATUS_OVERFLOW`] beyond it.
pub const REPLY_INLINE_CAP: usize = 64 << 10;
/// Trailer: `[frame_seq u64][r0 u64][total_len u64][payload_len u64][status u64][seq u64]`.
pub const REPLY_TRAILER_BYTES: usize = 48;
/// Bytes per reply frame slot.
pub const REPLY_FRAME_BYTES: usize = REPLY_INLINE_CAP + REPLY_TRAILER_BYTES;
/// Total reply-region bytes.
pub const REPLY_REGION_BYTES: usize = REPLY_SLOTS * REPLY_FRAME_BYTES;

// Trailer field offsets (relative to the trailer base).
const T_FRAME_SEQ: usize = 0;
const T_R0: usize = 8;
const T_TOTAL: usize = 16;
const T_LEN: usize = 24;
const T_STATUS: usize = 32;
const T_SEQ: usize = 40;

/// Frame executed to completion; `r0` is the injected main's return value.
pub const STATUS_OK: u64 = 1;
/// Frame consumed but rejected (decode/link/verify/runtime failure).
pub const STATUS_FAILED: u64 = 2;
/// Streaming disabled and the reply payload exceeded
/// [`REPLY_INLINE_CAP`]: the payload is dropped and only `r0` (for
/// `db_get`: the length the caller asked about) comes back. Kept for
/// wire compat with `stream_replies: false` workers — a streaming worker
/// never produces it.
pub const STATUS_OVERFLOW: u64 = 3;
/// A chunk of a streamed reply; more chunks follow at the next seqs. The
/// trailer's `r0` word holds this chunk's byte offset into the stream and
/// `total_len` the full payload size.
pub const STATUS_MORE: u64 = 4;

/// One invocation's reply: status + `r0` + the payload the injected
/// function pushed via the `reply_put` / `db_get` host symbols
/// (reassembled across chunk frames by the [`ReplyCollector`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Reply {
    /// Sequence number of the ingress frame this reply answers (1-based).
    pub seq: u64,
    /// [`STATUS_OK`], [`STATUS_FAILED`], or [`STATUS_OVERFLOW`].
    pub status: u64,
    /// `r0` at `HALT` (0 when the frame was rejected).
    pub r0: u64,
    /// Reply payload (empty unless the injected function pushed bytes).
    pub payload: Vec<u8>,
}

impl Reply {
    /// Whether the injected function ran to completion (an overflowed
    /// reply from a non-streaming worker did run, but reports
    /// [`STATUS_OVERFLOW`] so the payload loss is visible — it is *not*
    /// `ok`).
    pub fn ok(&self) -> bool {
        self.status == STATUS_OK
    }

    /// Whether the function executed on a `stream_replies: false` worker
    /// and its reply payload exceeded [`REPLY_INLINE_CAP`]. Streaming
    /// workers never overflow — any size ships chunked.
    pub fn overflowed(&self) -> bool {
        self.status == STATUS_OVERFLOW
    }

    /// Decode the payload as little-endian f32s (record bytes from
    /// `db_get`); trailing partial words are ignored.
    pub fn payload_f32s(&self) -> Vec<f32> {
        self.payload
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }
}

fn slot_off(seq: u64) -> usize {
    ((seq - 1) as usize % REPLY_SLOTS) * REPLY_FRAME_BYTES
}

/// Sender-side reply ring: a mapped region the worker puts frames into.
/// Cheap to clone (the mapping is shared) so `PendingReply` handles and
/// the [`ReplyCollector`] can use it without holding any link lock.
#[derive(Clone)]
pub struct ReplyRing {
    mr: Arc<MemoryRegion>,
    /// How long reply waits spin without progress before declaring the
    /// worker dead (`None` = forever).
    pub(crate) timeout: Option<Duration>,
}

impl ReplyRing {
    /// Map a reply region on `ctx` (the sender/leader side). `timeout`
    /// bounds every wait: a worker that dies mid-invoke surfaces as
    /// [`Error::Transport`] instead of hanging the leader.
    pub fn new(ctx: &Context, timeout: Option<Duration>) -> Self {
        // Reply frames are written and read, never remotely
        // atomically-updated: no reason to grant more than RW (the code
        // ring alone keeps RWX).
        ReplyRing { mr: ctx.mem_map(REPLY_REGION_BYTES, MemPerm::RW), timeout }
    }

    /// The rkey the worker-side [`ReplyWriter`] puts into.
    pub fn rkey(&self) -> RKey {
        self.mr.rkey()
    }

    /// The reply region itself, for a *colocated* writer
    /// ([`ReplyWriter::shm`]) that stores frames into the shared mapping
    /// directly instead of putting through a fabric endpoint.
    pub(crate) fn region(&self) -> Arc<MemoryRegion> {
        self.mr.clone()
    }

    /// Read the trailer + chunk of reply frame `seq` if it has fully
    /// arrived in its slot. Returns the inner `Err(word)` while the slot
    /// still holds an older (or zeroed) seq word — the observed word
    /// rides along for progress detection; hard-errors if the slot was
    /// lapped past `seq` or overwritten mid-copy (seqlock).
    fn read_frame(&self, seq: u64) -> Result<std::result::Result<RawFrame, u64>> {
        debug_assert!(seq > 0, "reply frame seqs are 1-based");
        let off = slot_off(seq);
        let trailer = off + REPLY_INLINE_CAP;
        let got = self.mr.load_u64_acquire(trailer + T_SEQ)?;
        if got < seq {
            return Ok(Err(got));
        }
        if got > seq {
            return Err(Error::Transport(format!(
                "reply frame {seq} overwritten (slot now holds seq {got})"
            )));
        }
        let frame_seq = self.mr.load_u64_acquire(trailer + T_FRAME_SEQ)?;
        let r0 = self.mr.load_u64_acquire(trailer + T_R0)?;
        let total_len = self.mr.load_u64_acquire(trailer + T_TOTAL)?;
        let len = self.mr.load_u64_acquire(trailer + T_LEN)? as usize;
        let status = self.mr.load_u64_acquire(trailer + T_STATUS)?;
        if len > REPLY_INLINE_CAP {
            return Err(Error::Transport(format!(
                "reply frame {seq} corrupt: payload_len {len}"
            )));
        }
        let chunk = self.mr.local_slice()[off..off + len].to_vec();
        // Seqlock re-check: a lap writer zeroes the seq word before
        // touching the slot, so a torn chunk copy is detectable. The
        // acquire fence is the reader half of that protocol (smp_rmb in a
        // classic seqlock): it keeps the plain chunk loads above from
        // being reordered past the validating seq load below on
        // weakly-ordered CPUs.
        std::sync::atomic::fence(std::sync::atomic::Ordering::Acquire);
        if self.mr.load_u64_acquire(trailer + T_SEQ)? != seq {
            return Err(Error::Transport(format!(
                "reply frame {seq} overwritten mid-read"
            )));
        }
        Ok(Ok(RawFrame { frame_seq, r0, total_len, len: len as u64, status, chunk }))
    }

    /// Spin until reply frame `seq` (1-based) arrives and copy it out —
    /// the **one-frame-per-ingress-frame** reader used when streaming is
    /// disabled (reply seq ≡ ingress frame seq). Errors if the slot was
    /// overwritten by a later lap of the ring, if the frame is a
    /// [`STATUS_MORE`] chunk (a streamed reply needs the
    /// [`ReplyCollector`]), or if the configured timeout expires first.
    /// The timeout is progress-based: any movement of the slot's seq word
    /// resets the deadline, so only a worker making *no* observable
    /// progress is declared dead.
    pub fn wait(&self, seq: u64) -> Result<Reply> {
        let mut deadline = self.timeout.map(|d| Instant::now() + d);
        let mut last_got: Option<u64> = None;
        let mut i = 0u32;
        loop {
            match self.read_frame(seq)? {
                Ok(f) => {
                    if f.status == STATUS_MORE {
                        return Err(Error::Transport(format!(
                            "reply frame {seq} is a stream chunk; this link was \
                             configured without reply streaming"
                        )));
                    }
                    return Ok(Reply {
                        seq,
                        status: f.status,
                        r0: f.r0,
                        payload: f.chunk,
                    });
                }
                Err(got) => {
                    if last_got != Some(got) {
                        last_got = Some(got);
                        deadline = self.timeout.map(|d| Instant::now() + d);
                    }
                }
            }
            if let Some(d) = deadline {
                if Instant::now() > d {
                    return Err(Error::Transport(format!(
                        "no reply-ring progress for {:?} while waiting for the reply \
                         to frame {seq} (worker dead or stalled?)",
                        self.timeout.unwrap_or_default()
                    )));
                }
            }
            crate::fabric::wire::backoff(i);
            i += 1;
        }
    }
}

/// A fully-arrived reply frame, fields straight off the wire.
struct RawFrame {
    frame_seq: u64,
    r0: u64,
    total_len: u64,
    len: u64,
    status: u64,
    chunk: Vec<u8>,
}

/// A reply frame built but possibly not yet placeable in the ring (the
/// slot it needs may still hold a chunk the collector has not consumed).
struct QueuedFrame {
    seq: u64,
    frame_seq: u64,
    status: u64,
    r0: u64,
    total_len: u64,
    chunk: Vec<u8>,
}

/// Worker-side reply writer bound to one sender's reply ring.
///
/// In streaming mode ([`ReplyWriter::with_mode`] with `stream = true`),
/// payloads larger than [`REPLY_INLINE_CAP`] split into chunk frames, and
/// a chunk is only placed in the ring once the collector's
/// collected-watermark credit says its slot is free — frames that cannot
/// be placed yet queue locally and drain on [`ReplyWriter::pump`]. The
/// writer therefore **never blocks**: a leader that is slow to collect
/// costs worker memory (bounded by its own uncollected backlog), never
/// worker liveness.
pub struct ReplyWriter {
    /// Where reply-frame puts land: a worker → sender endpoint (fabric
    /// links) or the leader's reply mapping shared directly (shm links).
    sink: PutSink,
    /// Reply frames assigned (queued or written).
    seq: u64,
    queue: VecDeque<QueuedFrame>,
    stream: bool,
    /// Worker-local word the leader's collector puts its consumed
    /// watermark into; `None` disables the credit gate (legacy mode, and
    /// wire-format unit harnesses that read promptly).
    credit: Option<Arc<MemoryRegion>>,
}

impl ReplyWriter {
    /// `ep` is a worker → sender endpoint; `rkey` names the sender's
    /// reply region. Legacy (non-streaming, uncredited) mode: one frame
    /// per push, [`STATUS_OVERFLOW`] past the cap.
    pub fn new(ep: Arc<Endpoint>, rkey: RKey) -> Self {
        Self::with_mode(ep, rkey, false, None)
    }

    /// Full constructor: `stream` turns big payloads into chunk streams;
    /// `credit` is the worker-local region holding the collector's
    /// consumed watermark (slot recycling gate).
    pub fn with_mode(
        ep: Arc<Endpoint>,
        rkey: RKey,
        stream: bool,
        credit: Option<Arc<MemoryRegion>>,
    ) -> Self {
        Self::with_sink(PutSink::Fabric { ep, rkey }, stream, credit)
    }

    /// Colocated (shm-link) writer: reply frames are stored straight into
    /// `ring`'s mapping — identical seqlock slot protocol, no endpoint.
    pub fn shm(
        ring: &ReplyRing,
        stream: bool,
        credit: Option<Arc<MemoryRegion>>,
    ) -> Self {
        Self::with_sink(PutSink::Shm(ring.region()), stream, credit)
    }

    fn with_sink(sink: PutSink, stream: bool, credit: Option<Arc<MemoryRegion>>) -> Self {
        ReplyWriter { sink, seq: 0, queue: VecDeque::new(), stream, credit }
    }

    /// Record the outcome of consumed ingress frame `frame_seq`; returns
    /// the reply seq of the stream's **final** frame. A payload within
    /// [`REPLY_INLINE_CAP`] ships as one frame; larger payloads ship as a
    /// chunk stream (streaming mode) or a payload-less
    /// [`STATUS_OVERFLOW`] frame with `r0` intact (legacy mode). Frames
    /// whose slots are not yet free queue locally (see
    /// [`ReplyWriter::pump`]).
    pub fn push(&mut self, frame_seq: u64, ok: bool, r0: u64, payload: &[u8]) -> Result<u64> {
        let total = payload.len() as u64;
        if !ok {
            self.enqueue(frame_seq, STATUS_FAILED, r0, 0, Vec::new());
        } else if payload.len() <= REPLY_INLINE_CAP {
            self.enqueue(frame_seq, STATUS_OK, r0, total, payload.to_vec());
        } else if !self.stream {
            self.enqueue(frame_seq, STATUS_OVERFLOW, r0, total, Vec::new());
        } else {
            let mut off = 0usize;
            while payload.len() - off > REPLY_INLINE_CAP {
                let chunk = payload[off..off + REPLY_INLINE_CAP].to_vec();
                self.enqueue(frame_seq, STATUS_MORE, off as u64, total, chunk);
                off += REPLY_INLINE_CAP;
            }
            self.enqueue(frame_seq, STATUS_OK, r0, total, payload[off..].to_vec());
        }
        let last = self.seq;
        self.pump()?;
        Ok(last)
    }

    fn enqueue(&mut self, frame_seq: u64, status: u64, r0: u64, total_len: u64, chunk: Vec<u8>) {
        self.seq += 1;
        let seq = self.seq;
        self.queue.push_back(QueuedFrame { seq, frame_seq, status, r0, total_len, chunk });
    }

    /// Place every queued frame whose slot the collector has released
    /// (`seq <= watermark + REPLY_SLOTS`). Non-blocking; the worker's
    /// receive loop calls this once per iteration so queued chunks drain
    /// as credit arrives. A frame whose puts fail is dropped (reported to
    /// the caller once) so a broken back-channel cannot wedge the loop in
    /// an error-retry spin.
    pub fn pump(&mut self) -> Result<()> {
        while let Some(front) = self.queue.front() {
            if let Some(credit) = &self.credit {
                let collected = credit.load_u64_acquire(0)?;
                if front.seq > collected + REPLY_SLOTS as u64 {
                    return Ok(());
                }
            }
            let f = self.queue.pop_front().unwrap();
            self.write_frame(&f)?;
        }
        Ok(())
    }

    /// Three ordered puts on one QP: seqlock-invalidate the slot, write
    /// the chunk, publish the trailer (seq word last).
    fn write_frame(&self, f: &QueuedFrame) -> Result<()> {
        let off = slot_off(f.seq);
        let trailer = off + REPLY_INLINE_CAP;
        // Invalidate before overwrite: a reader mid-copy of the previous
        // lap's chunk re-checks the seq word and sees 0, not stale data.
        self.sink.signal(trailer + T_SEQ, 0)?;
        if !f.chunk.is_empty() {
            self.sink.put(off, &f.chunk)?;
        }
        let mut t = [0u8; REPLY_TRAILER_BYTES];
        t[T_FRAME_SEQ..T_FRAME_SEQ + 8].copy_from_slice(&f.frame_seq.to_le_bytes());
        t[T_R0..T_R0 + 8].copy_from_slice(&f.r0.to_le_bytes());
        t[T_TOTAL..T_TOTAL + 8].copy_from_slice(&f.total_len.to_le_bytes());
        t[T_LEN..T_LEN + 8].copy_from_slice(&(f.chunk.len() as u64).to_le_bytes());
        t[T_STATUS..T_STATUS + 8].copy_from_slice(&f.status.to_le_bytes());
        t[T_SEQ..T_SEQ + 8].copy_from_slice(&f.seq.to_le_bytes());
        // The trailer put ends on the seq word, which both sinks deliver
        // as the release-stored tail — the publish of the whole frame.
        self.sink.put(trailer, &t)
    }

    /// Reply frames assigned so far (queued + written).
    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// Frames built but not yet placed in the ring (waiting on credit).
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Local completion of all placed reply frames (immediate on shm).
    pub fn flush(&self) -> Result<()> {
        self.sink.flush()
    }
}

/// A streamed reply mid-reassembly.
struct StreamInProgress {
    frame_seq: u64,
    total: u64,
    buf: Vec<u8>,
}

struct CollectorState {
    /// Next reply frame seq to consume (1-based, strictly sequential).
    next_seq: u64,
    /// Partially reassembled chunk stream, if any.
    cur: Option<StreamInProgress>,
    /// Ingress frame seqs with a registered waiter; completed replies for
    /// anyone else (fire-and-forget traffic) are dropped on the floor.
    awaited: BTreeSet<u64>,
    /// Reassembled, unclaimed replies keyed by ingress frame seq.
    ready: HashMap<u64, Reply>,
}

/// Leader-side reply consumer for streamed links: reads reply frames
/// **strictly in seq order**, reassembles chunk streams, parks replies
/// for registered waiters, and feeds the consumed watermark back to the
/// worker's [`ReplyWriter`] so slots recycle without laps.
///
/// The collector is driven cooperatively: [`ReplyCollector::collect`]
/// (a `PendingReply` waiting) and [`ReplyCollector::drain`] (every
/// fire-and-forget send, and the barrier wait) both advance it, so reply
/// frames are consumed even when nobody is waiting — which is what keeps
/// the worker-side queue bounded during floods. Because a k-chunk reply
/// occupies k reply seqs, this watermark — not a frame count — is the
/// unit the lap protection works in.
pub struct ReplyCollector {
    ring: ReplyRing,
    /// Where the watermark credit lands: a leader → worker endpoint put
    /// targeting the worker's credit word (fabric links), or the shared
    /// credit word stored directly (shm links).
    credit: PutSink,
    state: Mutex<CollectorState>,
}

/// One step of the collector: a frame was consumed, or the next frame has
/// not fully arrived (carrying the observed seq word for progress
/// detection).
enum Step {
    Consumed,
    Waiting(u64),
}

impl ReplyCollector {
    /// `ring` is the leader-side mapping the worker writes into; `ep` +
    /// `credit_rkey` name the worker-local watermark word the collector
    /// puts its progress into.
    pub fn new(ring: ReplyRing, ep: Arc<Endpoint>, credit_rkey: RKey) -> Self {
        Self::with_credit(ring, PutSink::Fabric { ep, rkey: credit_rkey })
    }

    /// Colocated (shm-link) collector: the watermark credit is stored
    /// straight into the shared `credit` word instead of put over a
    /// fabric endpoint.
    pub fn shm(ring: ReplyRing, credit: Arc<MemoryRegion>) -> Self {
        Self::with_credit(ring, PutSink::Shm(credit))
    }

    fn with_credit(ring: ReplyRing, credit: PutSink) -> Self {
        ReplyCollector {
            ring,
            credit,
            state: Mutex::new(CollectorState {
                next_seq: 1,
                cur: None,
                awaited: BTreeSet::new(),
                ready: HashMap::new(),
            }),
        }
    }

    /// Register ingress frame `frame_seq` as awaited **before its frame
    /// is sent** — the collector keeps (rather than drops) its reply when
    /// the stream completes. Call order matters: registering after the
    /// send races a concurrent drain.
    pub fn register(&self, frame_seq: u64) {
        // Collector locks deliberately keep std's poisoning semantics
        // (unlike the dispatcher/window locks, which recover): a chunk
        // stream mid-reassembly is multi-step state, and resuming from a
        // torn `cur` after a panic could splice a corrupted payload that
        // still reports ok. Poison-and-fail is the safe failure mode.
        self.state.lock().unwrap().awaited.insert(frame_seq);
    }

    /// Forget an awaited frame (waiter dropped without collecting); any
    /// parked reply is discarded.
    pub fn unregister(&self, frame_seq: u64) {
        let mut st = self.state.lock().unwrap();
        st.awaited.remove(&frame_seq);
        st.ready.remove(&frame_seq);
    }

    /// Frames currently registered as awaited — the stale-waiter probe
    /// for the drop-without-wait property tests (a dropped
    /// `PendingReply` / `MultiPendingReply` must leave this at zero).
    #[doc(hidden)]
    pub fn debug_awaited(&self) -> usize {
        self.state.lock().unwrap().awaited.len()
    }

    /// Consume every reply frame that has fully arrived, without
    /// blocking. Called from the send paths so collection keeps pace with
    /// injection even when no invocation is waiting.
    pub fn drain(&self) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        self.advance_batch(&mut st, None).map(|_| ())
    }

    /// Consume frames until the next one has not arrived — or until
    /// `stop_at`'s reply completes (a waiter should take its reply before
    /// the rest of the backlog is processed, and a *later* frame's error
    /// must not mask a reply that already reassembled) — then publish the
    /// watermark credit **once** for the whole batch (the writer only
    /// needs the latest value; one put per consumed frame would cost
    /// O(backlog) ops on the credit endpoint under the collector mutex).
    /// Returns the last [`Step::Waiting`] observation (0 when stopped
    /// early on `stop_at`).
    fn advance_batch(&self, st: &mut CollectorState, stop_at: Option<u64>) -> Result<u64> {
        let before = st.next_seq;
        let out = loop {
            if let Some(t) = stop_at {
                if st.ready.contains_key(&t) {
                    break Ok(0);
                }
            }
            match self.advance_one(st) {
                Ok(Step::Consumed) => continue,
                Ok(Step::Waiting(word)) => break Ok(word),
                Err(e) => break Err(e),
            }
        };
        if st.next_seq != before {
            self.credit.signal(0, st.next_seq - 1)?;
        }
        out
    }

    /// Block until the reply for ingress frame `frame_seq` is fully
    /// reassembled, driving the collector meanwhile. The timeout is
    /// progress-based: it resets whenever the collector consumes a frame
    /// or the next slot's seq word moves (a chunk mid-arrival).
    pub fn collect(&self, frame_seq: u64) -> Result<Reply> {
        let mut deadline = self.ring.timeout.map(|d| Instant::now() + d);
        let mut last_obs: Option<(u64, u64)> = None;
        let mut i = 0u32;
        loop {
            let obs;
            {
                let mut st = self.state.lock().unwrap();
                if let Some(r) = st.ready.remove(&frame_seq) {
                    st.awaited.remove(&frame_seq);
                    return Ok(r);
                }
                let word = self.advance_batch(&mut st, Some(frame_seq))?;
                if let Some(r) = st.ready.remove(&frame_seq) {
                    st.awaited.remove(&frame_seq);
                    return Ok(r);
                }
                obs = (st.next_seq, word);
            }
            if last_obs != Some(obs) {
                last_obs = Some(obs);
                deadline = self.ring.timeout.map(|d| Instant::now() + d);
            }
            if let Some(d) = deadline {
                if Instant::now() > d {
                    return Err(Error::Transport(format!(
                        "no reply-ring progress for {:?} while waiting for the reply \
                         to frame {frame_seq} (worker dead or stalled?)",
                        self.ring.timeout.unwrap_or_default()
                    )));
                }
            }
            crate::fabric::wire::backoff(i);
            i += 1;
        }
    }

    /// Try to consume the reply frame at `next_seq`: reassemble it into
    /// the current stream (or complete one), advance the watermark
    /// credit, and report progress. Chunk-splice hazards — a lap arriving
    /// mid-stream, chunks from different ingress frames, offset/total
    /// mismatches — are hard errors, never silent reassembly of bytes
    /// from two different replies.
    fn advance_one(&self, st: &mut CollectorState) -> Result<Step> {
        let seq = st.next_seq;
        let f = match self.ring.read_frame(seq)? {
            Ok(f) => f,
            Err(word) => return Ok(Step::Waiting(word)),
        };
        match f.status {
            STATUS_MORE => {
                let off = f.r0;
                match &mut st.cur {
                    None => {
                        if off != 0 {
                            return Err(Error::Transport(format!(
                                "reply stream for frame {} starts at chunk offset {off}, \
                                 not 0 (earlier chunks lapped?)",
                                f.frame_seq
                            )));
                        }
                        st.cur = Some(StreamInProgress {
                            frame_seq: f.frame_seq,
                            total: f.total_len,
                            buf: f.chunk,
                        });
                    }
                    Some(cur) => {
                        if cur.frame_seq != f.frame_seq
                            || cur.total != f.total_len
                            || off != cur.buf.len() as u64
                        {
                            return Err(Error::Transport(format!(
                                "reply chunk at seq {seq} does not continue the open \
                                 stream (frame {} offset {} vs chunk for frame {} \
                                 offset {off}) — refusing to splice replies",
                                cur.frame_seq,
                                cur.buf.len(),
                                f.frame_seq
                            )));
                        }
                        cur.buf.extend_from_slice(&f.chunk);
                    }
                }
            }
            STATUS_OK | STATUS_FAILED | STATUS_OVERFLOW => {
                let reply = match st.cur.take() {
                    Some(mut cur) => {
                        if cur.frame_seq != f.frame_seq || f.total_len != cur.total {
                            return Err(Error::Transport(format!(
                                "final reply chunk at seq {seq} answers frame {} but the \
                                 open stream belongs to frame {} — refusing to splice",
                                f.frame_seq, cur.frame_seq
                            )));
                        }
                        cur.buf.extend_from_slice(&f.chunk);
                        if cur.buf.len() as u64 != cur.total {
                            return Err(Error::Transport(format!(
                                "reply stream for frame {} reassembled to {} of {} bytes",
                                f.frame_seq,
                                cur.buf.len(),
                                cur.total
                            )));
                        }
                        Reply { seq: f.frame_seq, status: f.status, r0: f.r0, payload: cur.buf }
                    }
                    None => {
                        if f.status != STATUS_OVERFLOW && f.total_len != f.len {
                            return Err(Error::Transport(format!(
                                "single-frame reply for frame {} claims total_len {} \
                                 but carries {} bytes",
                                f.frame_seq, f.total_len, f.len
                            )));
                        }
                        Reply { seq: f.frame_seq, status: f.status, r0: f.r0, payload: f.chunk }
                    }
                };
                if st.awaited.contains(&reply.seq) {
                    st.ready.insert(reply.seq, reply);
                }
                // Unawaited (fire-and-forget) replies are dropped here.
            }
            other => {
                return Err(Error::Transport(format!(
                    "reply frame {seq} carries unknown status {other}"
                )));
            }
        }
        st.next_seq += 1;
        // The watermark credit is published by `advance_batch`, once per
        // batch of consumed frames.
        Ok(Step::Consumed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{Fabric, WireConfig};
    use crate::ucp::{ContextConfig, Worker};

    struct Harness {
        ring: ReplyRing,
        /// Worker-local credit word (the writer's gate; tests can also
        /// poke it directly to simulate rogue credit).
        credit: Arc<MemoryRegion>,
        /// Leader → worker ep for a collector.
        fwd_ep: Arc<Endpoint>,
    }

    fn harness(timeout: Option<Duration>) -> (Harness, ReplyWriter) {
        let f = Fabric::new(2, WireConfig::off());
        let leader = Context::new(f.node(0), ContextConfig::default()).unwrap();
        let worker = Context::new(f.node(1), ContextConfig::default()).unwrap();
        let wl = Worker::new(&leader);
        let ww = Worker::new(&worker);
        let ring = ReplyRing::new(&leader, timeout);
        let credit = worker.mem_map(64, MemPerm::RW);
        let ep = ww.connect(&wl).unwrap();
        let fwd_ep = wl.connect(&ww).unwrap();
        let rkey = ring.rkey();
        let writer = ReplyWriter::with_mode(ep, rkey, true, Some(credit.clone()));
        (Harness { ring, credit, fwd_ep }, writer)
    }

    fn collector(h: &Harness) -> ReplyCollector {
        ReplyCollector::new(h.ring.clone(), h.fwd_ep.clone(), h.credit.rkey())
    }

    /// Legacy pair: non-streaming, uncredited writer + slot reader.
    fn pair_with(timeout: Option<Duration>) -> (ReplyRing, ReplyWriter) {
        let f = Fabric::new(2, WireConfig::off());
        let leader = Context::new(f.node(0), ContextConfig::default()).unwrap();
        let worker = Context::new(f.node(1), ContextConfig::default()).unwrap();
        let wl = Worker::new(&leader);
        let ww = Worker::new(&worker);
        let ring = ReplyRing::new(&leader, timeout);
        let ep = ww.connect(&wl).unwrap();
        let rkey = ring.rkey();
        (ring, ReplyWriter::new(ep, rkey))
    }

    fn pair() -> (ReplyRing, ReplyWriter) {
        pair_with(None)
    }

    #[test]
    fn reply_roundtrip_preserves_r0_status_and_payload() {
        let (ring, mut w) = pair();
        w.push(1, true, 42, b"record bytes").unwrap();
        w.push(2, false, 0, &[]).unwrap();
        w.push(3, true, 7, &[]).unwrap();
        let r1 = ring.wait(1).unwrap();
        assert_eq!(
            r1,
            Reply { seq: 1, status: STATUS_OK, r0: 42, payload: b"record bytes".to_vec() }
        );
        assert!(r1.ok());
        let r2 = ring.wait(2).unwrap();
        assert_eq!(r2.status, STATUS_FAILED);
        assert!(!r2.ok() && r2.payload.is_empty());
        let r3 = ring.wait(3).unwrap();
        assert!(r3.ok() && r3.payload.is_empty());
        assert_eq!(r3.r0, 7);
    }

    #[test]
    fn legacy_oversized_payload_ships_overflow_with_r0_intact() {
        let (ring, mut w) = pair();
        let big = vec![0xA5u8; REPLY_INLINE_CAP + 1];
        w.push(1, true, big.len() as u64, &big).unwrap();
        let r = ring.wait(1).unwrap();
        assert!(r.overflowed() && !r.ok());
        assert!(r.payload.is_empty());
        // The old r0-as-length behavior: the caller learns the size.
        assert_eq!(r.r0, (REPLY_INLINE_CAP + 1) as u64);
    }

    #[test]
    fn slots_wrap_and_overwrite_is_detected() {
        let (ring, mut w) = pair();
        // Two full laps: reply seq N and N + REPLY_SLOTS share a slot.
        for i in 0..(2 * REPLY_SLOTS as u64) {
            w.push(i + 1, true, i, &i.to_le_bytes()).unwrap();
        }
        w.flush().unwrap();
        let last = 2 * REPLY_SLOTS as u64;
        let r = ring.wait(last).unwrap();
        assert_eq!(r.r0, last - 1);
        assert_eq!(r.payload, (last - 1).to_le_bytes());
        // The first lap's replies are gone; waiting for one must error,
        // not hand back the second lap's payload.
        assert!(ring.wait(1).is_err());
    }

    #[test]
    fn wait_times_out_when_no_reply_ever_arrives() {
        let (ring, _w) = pair_with(Some(Duration::from_millis(30)));
        let err = ring.wait(1).unwrap_err();
        assert!(
            matches!(&err, Error::Transport(m) if m.contains("no reply-ring progress")),
            "{err}"
        );
    }

    #[test]
    fn payload_f32s_decodes_record_bytes() {
        let r = Reply {
            seq: 1,
            status: STATUS_OK,
            r0: 2,
            payload: [1.5f32, -2.0].iter().flat_map(|v| v.to_le_bytes()).collect(),
        };
        assert_eq!(r.payload_f32s(), vec![1.5, -2.0]);
    }

    #[test]
    fn chunked_reply_reassembles_across_slots() {
        let (h, mut w) = harness(None);
        let c = collector(&h);
        let payload: Vec<u8> =
            (0..(2 * REPLY_INLINE_CAP + 1234)).map(|i| (i % 251) as u8).collect();
        c.register(1);
        let last = w.push(1, true, 99, &payload).unwrap();
        assert_eq!(last, 3, "2*CAP + rest = 3 chunk frames");
        w.flush().unwrap();
        let r = c.collect(1).unwrap();
        assert!(r.ok());
        assert_eq!(r.r0, 99);
        assert_eq!(r.seq, 1);
        assert_eq!(r.payload, payload);
    }

    #[test]
    fn exact_multiple_of_cap_has_no_empty_tail_chunk() {
        let (h, mut w) = harness(None);
        let c = collector(&h);
        let payload = vec![0x5Au8; 3 * REPLY_INLINE_CAP];
        c.register(1);
        let last = w.push(1, true, 7, &payload).unwrap();
        assert_eq!(last, 3, "k * CAP must ship exactly k chunks");
        w.flush().unwrap();
        let r = c.collect(1).unwrap();
        assert_eq!(r.payload, payload);
    }

    #[test]
    fn empty_payload_is_a_single_frame() {
        let (h, mut w) = harness(None);
        let c = collector(&h);
        c.register(1);
        assert_eq!(w.push(1, true, 3, &[]).unwrap(), 1);
        w.flush().unwrap();
        let r = c.collect(1).unwrap();
        assert!(r.ok() && r.payload.is_empty());
        assert_eq!(r.r0, 3);
    }

    #[test]
    fn writer_queues_past_credit_and_drains_on_pump() {
        let (h, mut w) = harness(None);
        // A stream longer than the whole ring: only REPLY_SLOTS chunks
        // can be placed before the collector grants more credit.
        let chunks = REPLY_SLOTS + 9;
        let payload = vec![1u8; chunks * REPLY_INLINE_CAP];
        w.push(1, true, 1, &payload).unwrap();
        assert_eq!(w.pending(), 9, "chunks past the ring must queue, not lap");
        // Simulate the collector consuming everything so far.
        h.credit.store_u64_release(0, REPLY_SLOTS as u64).unwrap();
        w.pump().unwrap();
        assert_eq!(w.pending(), 0);
    }

    #[test]
    fn collector_streams_a_reply_larger_than_the_ring() {
        let (h, mut w) = harness(None);
        let c = Arc::new(collector(&h));
        let chunks = REPLY_SLOTS + 17;
        let payload: Vec<u8> =
            (0..chunks * REPLY_INLINE_CAP).map(|i| (i % 239) as u8).collect();
        c.register(1);
        w.push(1, true, 42, &payload).unwrap();
        let c2 = c.clone();
        let t = std::thread::spawn(move || c2.collect(1));
        // Drain the worker-side queue as the collector grants credit.
        while w.pending() > 0 {
            w.pump().unwrap();
            std::thread::yield_now();
        }
        w.flush().unwrap();
        let r = t.join().unwrap().unwrap();
        assert_eq!(r.payload, payload);
        assert_eq!(r.r0, 42);
    }

    #[test]
    fn fire_and_forget_replies_are_drained_not_hoarded() {
        let (h, mut w) = harness(None);
        let c = collector(&h);
        for i in 1..=10u64 {
            w.push(i, true, i, &[]).unwrap();
        }
        w.flush().unwrap();
        c.drain().unwrap();
        // Nothing registered, so nothing parked — and the watermark
        // reached the writer (flush: credit puts are asynchronous).
        h.fwd_ep.flush().unwrap();
        assert_eq!(h.credit.load_u64_acquire(0).unwrap(), 10);
        assert!(c.state.lock().unwrap().ready.is_empty());
    }

    #[test]
    fn lap_mid_stream_errors_instead_of_splicing() {
        let (h, mut w) = harness(None);
        let c = collector(&h);
        c.register(1);
        // A stream one lap longer than the ring, with the credit gate in
        // place: the writer parks the chunks past slot REPLY_SLOTS.
        let chunks = REPLY_SLOTS + 6;
        let payload = vec![9u8; chunks * REPLY_INLINE_CAP];
        w.push(1, true, 0, &payload).unwrap();
        // Rogue credit (a buggy or hostile collector impl): the writer
        // now laps the *unread* head of its own stream.
        h.credit.store_u64_release(0, chunks as u64).unwrap();
        w.pump().unwrap();
        w.flush().unwrap();
        // The collector must refuse to stitch chunk 65 (offset 64*CAP)
        // in place of lapped chunk 1 — error, never a spliced payload.
        let err = c.collect(1).unwrap_err();
        assert!(
            err.to_string().contains("overwritten") || err.to_string().contains("lapped"),
            "{err}"
        );
    }

    /// The colocated flavor of the whole reply path: writer, chunk
    /// stream, credit gate, and collector all ride shared mappings — no
    /// endpoint anywhere — and behave identically to the fabric pair.
    #[test]
    fn shm_writer_and_collector_stream_a_chunked_reply() {
        let f = Fabric::new(1, WireConfig::off());
        let leader = Context::new(f.node(0), ContextConfig::default()).unwrap();
        let ring = ReplyRing::new(&leader, None);
        let credit = leader.mem_map(64, MemPerm::RW);
        let c = ReplyCollector::shm(ring.clone(), credit.clone());
        let mut w = ReplyWriter::shm(&ring, true, Some(credit));
        let payload: Vec<u8> =
            (0..(2 * REPLY_INLINE_CAP + 777)).map(|i| (i % 253) as u8).collect();
        c.register(1);
        let last = w.push(1, true, 11, &payload).unwrap();
        assert_eq!(last, 3);
        w.flush().unwrap();
        let r = c.collect(1).unwrap();
        assert!(r.ok());
        assert_eq!(r.r0, 11);
        assert_eq!(r.payload, payload);
        // Fire-and-forget replies drain and feed the shared watermark
        // word synchronously (no endpoint flush needed on shm).
        for i in 2..=5u64 {
            w.push(i, true, i, &[]).unwrap();
        }
        c.drain().unwrap();
        assert_eq!(w.pending(), 0);
    }

    #[test]
    fn streaming_reply_on_legacy_reader_is_an_error() {
        let (h, mut w) = harness(None);
        let payload = vec![0u8; REPLY_INLINE_CAP + 1];
        w.push(1, true, 0, &payload).unwrap();
        w.flush().unwrap();
        let err = h.ring.wait(1).unwrap_err();
        assert!(err.to_string().contains("stream chunk"), "{err}");
    }
}
