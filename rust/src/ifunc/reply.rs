//! The invocation reply path: a per-worker ring of payload-carrying
//! **reply frames** flowing target → sender.
//!
//! The paper's ifuncs are fire-and-forget; anything the injected function
//! computes stays on the target. This module is the missing half of an
//! *invocation* (§5): after the execution engine finishes frame `seq` (the
//! `seq`-th frame delivered on the link, counting executed **and**
//! rejected frames), the worker writes one reply frame into a
//! leader-mapped reply region with one-sided puts — the same mechanism
//! data frames travel by, just pointed back at the sender. Each frame
//! occupies a fixed [`REPLY_FRAME_BYTES`] slot so the reader can find
//! frame `seq` without parsing the stream, but carries a *variable*
//! payload of up to [`REPLY_INLINE_CAP`] bytes:
//!
//! ```text
//!  | payload      | REPLY_INLINE_CAP B   reply bytes (first payload_len valid)
//!  | r0           | 8 B   injected main's return value (0 when rejected)
//!  | payload_len  | 8 B   valid payload bytes (0 on overflow/failure)
//!  | status       | 8 B   1 = ok, 2 = rejected, 3 = payload overflow
//!  | seq          | 8 B   frame sequence number, written last
//! ```
//!
//! `seq` is the arrival barrier: the fabric delivers the final word of a
//! put last (the trailer-signal property of §3.4), and the trailer put is
//! issued *after* the payload put on the same in-order QP, so once the
//! reader observes `seq` in a slot, every other field — payload included —
//! has landed. Slots are reused modulo [`REPLY_SLOTS`]; the writer runs a
//! seqlock protocol (zero the seq word, write payload + trailer, publish
//! the new seq last), and because the full 64-bit seq is stored, a reader
//! that waited too long detects the overwrite — before or mid-copy —
//! instead of misreading a later lap's payload.
//!
//! A reply payload larger than [`REPLY_INLINE_CAP`] is not truncated: the
//! frame ships with [`STATUS_OVERFLOW`], an empty payload, and the
//! injected function's `r0` intact — for `db_get` that is the old
//! r0-as-length behavior, telling the caller how big the record it could
//! not inline is.
//!
//! Both transports share this channel — it doubles as the completion
//! credit `Dispatcher::barrier` waits on (the reply for the last frame
//! sent implies, by in-order delivery, that every frame was consumed).

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::fabric::{MemPerm, MemoryRegion, RKey};
use crate::ucp::{Context, Endpoint};
use crate::{Error, Result};

/// Frames in a reply ring. Replies are read promptly (an `invoke` waits
/// for its own seq, `barrier` for the last, and the coordinator caps
/// outstanding invocations at `ClusterConfig::max_inflight <= REPLY_SLOTS`
/// so invocation replies cannot lap their readers).
pub const REPLY_SLOTS: usize = 64;
/// Largest payload a reply frame carries inline — sized to the largest
/// record the deleted leader-side result region could return (64 KiB =
/// 16384 f32s), so the refactor sheds no capability. Bigger results ship
/// as [`STATUS_OVERFLOW`] with `r0` intact (for `db_get`: the record
/// length).
pub const REPLY_INLINE_CAP: usize = 64 << 10;
/// Trailer: `[r0 u64][payload_len u64][status u64][seq u64]`.
pub const REPLY_TRAILER_BYTES: usize = 32;
/// Bytes per reply frame slot.
pub const REPLY_FRAME_BYTES: usize = REPLY_INLINE_CAP + REPLY_TRAILER_BYTES;
/// Total reply-region bytes.
pub const REPLY_REGION_BYTES: usize = REPLY_SLOTS * REPLY_FRAME_BYTES;

/// Frame executed to completion; `r0` is the injected main's return value.
pub const STATUS_OK: u64 = 1;
/// Frame consumed but rejected (decode/link/verify/runtime failure).
pub const STATUS_FAILED: u64 = 2;
/// Frame executed, but its reply payload exceeded [`REPLY_INLINE_CAP`]:
/// the payload is dropped and only `r0` (for `db_get`: the length the
/// caller asked about) comes back.
pub const STATUS_OVERFLOW: u64 = 3;

/// One invocation's reply: status + `r0` + the inline payload the injected
/// function pushed via the `reply_put` / `db_get` host symbols.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Reply {
    /// Sequence number of the frame this reply answers (1-based).
    pub seq: u64,
    /// [`STATUS_OK`], [`STATUS_FAILED`], or [`STATUS_OVERFLOW`].
    pub status: u64,
    /// `r0` at `HALT` (0 when the frame was rejected).
    pub r0: u64,
    /// Inline reply payload (empty unless the injected function pushed
    /// bytes and they fit [`REPLY_INLINE_CAP`]).
    pub payload: Vec<u8>,
}

impl Reply {
    /// Whether the injected function ran to completion (overflowed replies
    /// did run, but report [`STATUS_OVERFLOW`] so the payload loss is
    /// visible — they are *not* `ok`).
    pub fn ok(&self) -> bool {
        self.status == STATUS_OK
    }

    /// Whether the function executed but its reply payload exceeded
    /// [`REPLY_INLINE_CAP`].
    pub fn overflowed(&self) -> bool {
        self.status == STATUS_OVERFLOW
    }

    /// Decode the payload as little-endian f32s (record bytes from
    /// `db_get`); trailing partial words are ignored.
    pub fn payload_f32s(&self) -> Vec<f32> {
        self.payload
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }
}

fn slot_off(seq: u64) -> usize {
    ((seq - 1) as usize % REPLY_SLOTS) * REPLY_FRAME_BYTES
}

/// Sender-side reply ring: a mapped region the worker puts frames into.
/// Cheap to clone (the mapping is shared) so `PendingReply` handles can
/// wait on it without holding any link lock.
#[derive(Clone)]
pub struct ReplyRing {
    mr: Arc<MemoryRegion>,
    /// How long [`ReplyRing::wait`] spins before declaring the worker dead
    /// (`None` = forever).
    timeout: Option<Duration>,
}

impl ReplyRing {
    /// Map a reply region on `ctx` (the sender/leader side). `timeout`
    /// bounds every [`ReplyRing::wait`]: a worker that dies mid-invoke
    /// surfaces as [`Error::Transport`] instead of hanging the leader.
    pub fn new(ctx: &Context, timeout: Option<Duration>) -> Self {
        ReplyRing { mr: ctx.mem_map(REPLY_REGION_BYTES, MemPerm::RWX), timeout }
    }

    /// The rkey the worker-side [`ReplyWriter`] puts into.
    pub fn rkey(&self) -> RKey {
        self.mr.rkey()
    }

    /// Spin until the reply frame for `seq` (1-based) arrives and copy it
    /// out. Errors if the slot was overwritten by a later lap of the ring
    /// (detected before *and* mid-copy via the seqlock word), or if the
    /// configured timeout expires first. The timeout is progress-based:
    /// any movement of the slot's seq word (a slow worker draining a
    /// backlog laps this slot every `REPLY_SLOTS` frames) resets the
    /// deadline, so only a worker making *no* observable progress is
    /// declared dead.
    pub fn wait(&self, seq: u64) -> Result<Reply> {
        debug_assert!(seq > 0, "frame seqs are 1-based");
        let off = slot_off(seq);
        let trailer = off + REPLY_INLINE_CAP;
        let mut deadline = self.timeout.map(|d| Instant::now() + d);
        let mut last_got: Option<u64> = None;
        let mut i = 0u32;
        loop {
            // seq occupies the frame's final word, so it lands last.
            let got = self.mr.load_u64_acquire(trailer + 24)?;
            if last_got != Some(got) {
                last_got = Some(got);
                deadline = self.timeout.map(|d| Instant::now() + d);
            }
            if got == seq {
                let r0 = self.mr.load_u64_acquire(trailer)?;
                let len = self.mr.load_u64_acquire(trailer + 8)? as usize;
                let status = self.mr.load_u64_acquire(trailer + 16)?;
                if len > REPLY_INLINE_CAP {
                    return Err(Error::Transport(format!(
                        "reply frame for seq {seq} corrupt: payload_len {len}"
                    )));
                }
                let payload = self.mr.local_slice()[off..off + len].to_vec();
                // Seqlock re-check: a lap writer zeroes the seq word before
                // touching the slot, so a torn payload copy is detectable.
                // The acquire fence is the reader half of that protocol
                // (smp_rmb in a classic seqlock): it keeps the plain
                // payload loads above from being reordered past the
                // validating seq load below on weakly-ordered CPUs.
                std::sync::atomic::fence(std::sync::atomic::Ordering::Acquire);
                if self.mr.load_u64_acquire(trailer + 24)? != seq {
                    return Err(Error::Transport(format!(
                        "reply for frame {seq} overwritten mid-read"
                    )));
                }
                return Ok(Reply { seq, status, r0, payload });
            }
            if got > seq {
                return Err(Error::Transport(format!(
                    "reply for frame {seq} overwritten (slot now holds seq {got})"
                )));
            }
            if let Some(d) = deadline {
                if Instant::now() > d {
                    return Err(Error::Transport(format!(
                        "no reply-ring progress for {:?} while waiting for the reply \
                         to frame {seq} (worker dead or stalled?)",
                        self.timeout.unwrap_or_default()
                    )));
                }
            }
            crate::fabric::wire::backoff(i);
            i += 1;
        }
    }
}

/// Worker-side reply writer bound to one sender's reply ring.
pub struct ReplyWriter {
    ep: Arc<Endpoint>,
    rkey: RKey,
    seq: u64,
}

impl ReplyWriter {
    /// `ep` is a worker → sender endpoint; `rkey` names the sender's
    /// reply region.
    pub fn new(ep: Arc<Endpoint>, rkey: RKey) -> Self {
        ReplyWriter { ep, rkey, seq: 0 }
    }

    /// Record the outcome of the next consumed frame; returns its seq.
    /// `payload` rides inline when it fits [`REPLY_INLINE_CAP`]; larger
    /// payloads are dropped and the frame ships [`STATUS_OVERFLOW`] with
    /// `r0` intact. Three ordered puts on one QP: seqlock-invalidate the
    /// slot, write the payload, publish the trailer (seq word last).
    pub fn push(&mut self, ok: bool, r0: u64, payload: &[u8]) -> Result<u64> {
        self.seq += 1;
        let off = slot_off(self.seq);
        let trailer = off + REPLY_INLINE_CAP;
        // Invalidate before overwrite: a reader mid-copy of the previous
        // lap's payload re-checks the seq word and sees 0, not stale data.
        self.ep.put_nbi(self.rkey, trailer + 24, &0u64.to_le_bytes())?;
        let status = if !ok {
            STATUS_FAILED
        } else if payload.len() > REPLY_INLINE_CAP {
            STATUS_OVERFLOW
        } else {
            STATUS_OK
        };
        let payload = if status == STATUS_OK { payload } else { &[] };
        if !payload.is_empty() {
            self.ep.put_nbi(self.rkey, off, payload)?;
        }
        let mut t = [0u8; REPLY_TRAILER_BYTES];
        t[0..8].copy_from_slice(&r0.to_le_bytes());
        t[8..16].copy_from_slice(&(payload.len() as u64).to_le_bytes());
        t[16..24].copy_from_slice(&status.to_le_bytes());
        t[24..32].copy_from_slice(&self.seq.to_le_bytes());
        self.ep.put_nbi(self.rkey, trailer, &t)?;
        Ok(self.seq)
    }

    /// Frames replied to so far.
    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// Local completion of all pushed replies.
    pub fn flush(&self) -> Result<()> {
        self.ep.qp().flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{Fabric, WireConfig};
    use crate::ucp::{ContextConfig, Worker};

    fn pair_with(timeout: Option<Duration>) -> (ReplyRing, ReplyWriter) {
        let f = Fabric::new(2, WireConfig::off());
        let leader = Context::new(f.node(0), ContextConfig::default()).unwrap();
        let worker = Context::new(f.node(1), ContextConfig::default()).unwrap();
        let wl = Worker::new(&leader);
        let ww = Worker::new(&worker);
        let ring = ReplyRing::new(&leader, timeout);
        let ep = ww.connect(&wl).unwrap();
        let rkey = ring.rkey();
        (ring, ReplyWriter::new(ep, rkey))
    }

    fn pair() -> (ReplyRing, ReplyWriter) {
        pair_with(None)
    }

    #[test]
    fn reply_roundtrip_preserves_r0_status_and_payload() {
        let (ring, mut w) = pair();
        w.push(true, 42, b"record bytes").unwrap();
        w.push(false, 0, &[]).unwrap();
        w.push(true, 7, &[]).unwrap();
        let r1 = ring.wait(1).unwrap();
        assert_eq!(
            r1,
            Reply { seq: 1, status: STATUS_OK, r0: 42, payload: b"record bytes".to_vec() }
        );
        assert!(r1.ok());
        let r2 = ring.wait(2).unwrap();
        assert_eq!(r2.status, STATUS_FAILED);
        assert!(!r2.ok() && r2.payload.is_empty());
        let r3 = ring.wait(3).unwrap();
        assert!(r3.ok() && r3.payload.is_empty());
        assert_eq!(r3.r0, 7);
    }

    #[test]
    fn oversized_payload_ships_overflow_with_r0_intact() {
        let (ring, mut w) = pair();
        let big = vec![0xA5u8; REPLY_INLINE_CAP + 1];
        w.push(true, big.len() as u64, &big).unwrap();
        let r = ring.wait(1).unwrap();
        assert!(r.overflowed() && !r.ok());
        assert!(r.payload.is_empty());
        // The old r0-as-length behavior: the caller learns the size.
        assert_eq!(r.r0, (REPLY_INLINE_CAP + 1) as u64);
    }

    #[test]
    fn slots_wrap_and_overwrite_is_detected() {
        let (ring, mut w) = pair();
        // Two full laps: seq N and N + REPLY_SLOTS share a slot.
        for i in 0..(2 * REPLY_SLOTS as u64) {
            w.push(true, i, &i.to_le_bytes()).unwrap();
        }
        w.flush().unwrap();
        let last = 2 * REPLY_SLOTS as u64;
        let r = ring.wait(last).unwrap();
        assert_eq!(r.r0, last - 1);
        assert_eq!(r.payload, (last - 1).to_le_bytes());
        // The first lap's replies are gone; waiting for one must error,
        // not hand back the second lap's payload.
        assert!(ring.wait(1).is_err());
    }

    #[test]
    fn wait_times_out_when_no_reply_ever_arrives() {
        let (ring, _w) = pair_with(Some(Duration::from_millis(30)));
        let err = ring.wait(1).unwrap_err();
        assert!(
            matches!(&err, Error::Transport(m) if m.contains("no reply-ring progress")),
            "{err}"
        );
    }

    #[test]
    fn payload_f32s_decodes_record_bytes() {
        let r = Reply {
            seq: 1,
            status: STATUS_OK,
            r0: 2,
            payload: [1.5f32, -2.0].iter().flat_map(|v| v.to_le_bytes()).collect(),
        };
        assert_eq!(r.payload_f32s(), vec![1.5, -2.0]);
    }
}
