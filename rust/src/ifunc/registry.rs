//! Source-side ifunc registration — `ucp_register_ifunc`,
//! `ucp_deregister_ifunc`, `ucp_ifunc_msg_create` (Listing 1.1).

use std::sync::Arc;

use crate::ucp::Context;
use crate::vm::{self, AdmissionFacts};
use crate::Result;

use super::library::{IfuncLibrary, SourceArgs};
use super::message::{CodeImage, IfuncMsg, IfuncMsgParams};

/// Handle to a registered ifunc (`ucp_ifunc_h`). Holds the loaded library
/// and its code image, captured once at registration time — the analog of
/// the `dlopen` + `.text` extraction the paper's runtime performs.
pub struct IfuncHandle {
    lib: Arc<dyn IfuncLibrary>,
    code: CodeImage,
    params: IfuncMsgParams,
    /// Source-side static summary (fuel floor, may-loop verdict, reachable
    /// host calls), computed once here so every `msg_create` stamps it for
    /// free. `None` when the code fails local verification — the message
    /// still ships and the *target* produces the authoritative rejection.
    facts: Option<Arc<AdmissionFacts>>,
}

impl IfuncHandle {
    pub fn name(&self) -> &str {
        self.lib.name()
    }

    pub fn code(&self) -> &CodeImage {
        &self.code
    }

    /// The admission summary stamped onto messages from this handle.
    pub fn admission_facts(&self) -> Option<&AdmissionFacts> {
        self.facts.as_deref()
    }

    /// `ucp_ifunc_msg_create`: size the payload with
    /// `payload_get_max_size`, build the frame, fill the payload in place
    /// with `payload_init` ("this way, we eliminate unnecessary memory
    /// copies", §3.1), and shrink the frame if init used less than max.
    pub fn msg_create(&self, source_args: &SourceArgs) -> Result<IfuncMsg> {
        self.msg_create_with(source_args, self.params)
    }

    /// `msg_create` with explicit frame parameters (payload alignment —
    /// the §5.1 extension).
    pub fn msg_create_with(
        &self,
        source_args: &SourceArgs,
        params: IfuncMsgParams,
    ) -> Result<IfuncMsg> {
        let max = self.lib.payload_get_max_size(source_args);
        let mut msg =
            IfuncMsg::assemble_with(self.name(), &self.code, max, params, |payload| {
                self.lib.payload_init(payload, source_args)
            })?;
        msg.set_admission_facts(self.facts.clone());
        Ok(msg)
    }
}

impl Context {
    /// `ucp_register_ifunc`: resolve `name` in the library directory
    /// (`UCX_IFUNC_LIB_DIR`), load it, and return a handle messages can be
    /// created from.
    pub fn register_ifunc(&self, name: &str) -> Result<IfuncHandle> {
        let lib = self.library_dir().open(name)?;
        let code = lib.code();
        // One source-side verify + analyze per registration: its
        // AdmissionFacts ride every message this handle creates, letting
        // dispatchers refuse doomed invocations before fan-out.
        let facts = vm::verify(&code.vm_code, code.imports.len())
            .map(|instrs| {
                Arc::new(AdmissionFacts::derive(&vm::analyze(&instrs), &code.imports))
            })
            .ok();
        Ok(IfuncHandle { lib, code, params: IfuncMsgParams::default(), facts })
    }

    /// `ucp_deregister_ifunc`: drop the handle and invalidate any
    /// target-side cache entry this context holds for the name (relevant
    /// when a context is both source and target, e.g. loopback).
    pub fn deregister_ifunc(&self, h: IfuncHandle) {
        self.cache.invalidate(h.name());
        drop(h);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{Fabric, WireConfig};
    use crate::ifunc::builtin::CounterIfunc;
    use crate::ucp::ContextConfig;

    fn ctx() -> Arc<Context> {
        let f = Fabric::new(1, WireConfig::off());
        Context::new(f.node(0), ContextConfig::default()).unwrap()
    }

    #[test]
    fn register_unknown_name_fails() {
        let c = ctx();
        assert!(c.register_ifunc("missing").is_err());
    }

    #[test]
    fn register_and_create_message() {
        let c = ctx();
        c.library_dir().install(Box::new(CounterIfunc::default()));
        let h = c.register_ifunc("counter").unwrap();
        let msg = h.msg_create(&SourceArgs::bytes(vec![9u8; 100])).unwrap();
        assert_eq!(msg.name(), "counter");
        assert_eq!(msg.payload(), &[9u8; 100]);
    }

    #[test]
    fn messages_carry_admission_facts() {
        let c = ctx();
        c.library_dir().install(Box::new(CounterIfunc::default()));
        let h = c.register_ifunc("counter").unwrap();
        let facts = h.admission_facts().expect("counter verifies locally");
        assert!(!facts.may_loop, "straight-line body");
        assert!(facts.fuel_floor > 0, "at least the halt must retire");
        assert!(
            facts.reachable_syms.iter().any(|s| s == "counter_add"),
            "reachable call surface names the import: {:?}",
            facts.reachable_syms
        );
        let msg = h.msg_create(&SourceArgs::bytes(vec![0u8; 8])).unwrap();
        assert_eq!(msg.admission_facts(), h.admission_facts());
        // Hand-assembled frames carry no facts (and thus skip admission).
        let raw = IfuncMsg::assemble("counter", h.code(), &[0u8; 8], Default::default())
            .unwrap();
        assert!(raw.admission_facts().is_none());
    }

    #[test]
    fn deregister_invalidates_cache() {
        let c = ctx();
        c.library_dir().install(Box::new(CounterIfunc::default()));
        let h = c.register_ifunc("counter").unwrap();
        c.deregister_ifunc(h);
        // Registration is still possible afterwards.
        assert!(c.register_ifunc("counter").is_ok());
    }
}
