//! Source-side ifunc registration — `ucp_register_ifunc`,
//! `ucp_deregister_ifunc`, `ucp_ifunc_msg_create` (Listing 1.1).

use std::sync::Arc;

use crate::ucp::Context;
use crate::Result;

use super::library::{IfuncLibrary, SourceArgs};
use super::message::{CodeImage, IfuncMsg, IfuncMsgParams};

/// Handle to a registered ifunc (`ucp_ifunc_h`). Holds the loaded library
/// and its code image, captured once at registration time — the analog of
/// the `dlopen` + `.text` extraction the paper's runtime performs.
pub struct IfuncHandle {
    lib: Arc<dyn IfuncLibrary>,
    code: CodeImage,
    params: IfuncMsgParams,
}

impl IfuncHandle {
    pub fn name(&self) -> &str {
        self.lib.name()
    }

    pub fn code(&self) -> &CodeImage {
        &self.code
    }

    /// `ucp_ifunc_msg_create`: size the payload with
    /// `payload_get_max_size`, build the frame, fill the payload in place
    /// with `payload_init` ("this way, we eliminate unnecessary memory
    /// copies", §3.1), and shrink the frame if init used less than max.
    pub fn msg_create(&self, source_args: &SourceArgs) -> Result<IfuncMsg> {
        let max = self.lib.payload_get_max_size(source_args);
        IfuncMsg::assemble_with(self.name(), &self.code, max, self.params, |payload| {
            self.lib.payload_init(payload, source_args)
        })
    }

    /// `msg_create` with explicit frame parameters (payload alignment —
    /// the §5.1 extension).
    pub fn msg_create_with(
        &self,
        source_args: &SourceArgs,
        params: IfuncMsgParams,
    ) -> Result<IfuncMsg> {
        let max = self.lib.payload_get_max_size(source_args);
        IfuncMsg::assemble_with(self.name(), &self.code, max, params, |payload| {
            self.lib.payload_init(payload, source_args)
        })
    }
}

impl Context {
    /// `ucp_register_ifunc`: resolve `name` in the library directory
    /// (`UCX_IFUNC_LIB_DIR`), load it, and return a handle messages can be
    /// created from.
    pub fn register_ifunc(&self, name: &str) -> Result<IfuncHandle> {
        let lib = self.library_dir().open(name)?;
        let code = lib.code();
        Ok(IfuncHandle { lib, code, params: IfuncMsgParams::default() })
    }

    /// `ucp_deregister_ifunc`: drop the handle and invalidate any
    /// target-side cache entry this context holds for the name (relevant
    /// when a context is both source and target, e.g. loopback).
    pub fn deregister_ifunc(&self, h: IfuncHandle) {
        self.cache.invalidate(h.name());
        drop(h);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{Fabric, WireConfig};
    use crate::ifunc::builtin::CounterIfunc;
    use crate::ucp::ContextConfig;

    fn ctx() -> Arc<Context> {
        let f = Fabric::new(1, WireConfig::off());
        Context::new(f.node(0), ContextConfig::default()).unwrap()
    }

    #[test]
    fn register_unknown_name_fails() {
        let c = ctx();
        assert!(c.register_ifunc("missing").is_err());
    }

    #[test]
    fn register_and_create_message() {
        let c = ctx();
        c.library_dir().install(Box::new(CounterIfunc::default()));
        let h = c.register_ifunc("counter").unwrap();
        let msg = h.msg_create(&SourceArgs::bytes(vec![9u8; 100])).unwrap();
        assert_eq!(msg.name(), "counter");
        assert_eq!(msg.payload(), &[9u8; 100]);
    }

    #[test]
    fn deregister_invalidates_cache() {
        let c = ctx();
        c.library_dir().install(Box::new(CounterIfunc::default()));
        let h = c.register_ifunc("counter").unwrap();
        c.deregister_ifunc(h);
        // Registration is still possible afterwards.
        assert!(c.register_ifunc("counter").is_ok());
    }
}
