//! Auto-registration cache — the hash table of §3.4.
//!
//! "the `ucp_poll_ifunc` routine uses the ifunc's name provided by the
//! message header to attempt the auto-registration of any first-seen ifunc
//! type. If the corresponding library is found and loaded successfully,
//! the UCX runtime will patch the alternative GOT pointer ... and store
//! the related information in a hash table for subsequent messages of the
//! same type."
//!
//! A cache entry holds the reconstructed GOT (name-resolved bindings in
//! slot order), the import list it was resolved for, and whether the
//! ifunc's HLO artifact has been handed to the PJRT runtime. The entry id
//! is what gets *patched into the message's GOT slot* before invocation.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::vm::GotTable;

/// A linked (auto-registered) ifunc type.
pub struct LinkedIfunc {
    /// Entry id — the value patched into the frame's GOT slot.
    pub id: u32,
    pub name: String,
    /// Import names the GOT was resolved against, in slot order. If a
    /// later message under the same name ships a different import list
    /// ("the code can be modified anytime under the same ifunc name"), the
    /// poll path relinks and replaces this entry.
    pub imports: Vec<String>,
    pub got: GotTable,
    /// Whether this type shipped an HLO artifact (compiled per-thread by
    /// the PJRT runtime on first execution).
    pub has_hlo: bool,
}

#[derive(Default)]
pub struct IfuncCache {
    map: RwLock<HashMap<String, Arc<LinkedIfunc>>>,
    next_id: AtomicU64,
    pub hits: AtomicU64,
    pub misses: AtomicU64,
    /// If false, every message is relinked from scratch (ablation Abl B —
    /// quantifies what the paper's hash table saves).
    pub enabled: std::sync::atomic::AtomicBool,
}

impl IfuncCache {
    pub fn new() -> Self {
        let c = IfuncCache::default();
        c.enabled.store(true, Ordering::Relaxed);
        c
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    pub fn lookup(&self, name: &str) -> Option<Arc<LinkedIfunc>> {
        if !self.enabled.load(Ordering::Relaxed) {
            self.misses.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        let hit = self.map.read().unwrap().get(name).cloned();
        if hit.is_some() {
            self.hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// Insert (or replace) the entry for `name`; returns it with a fresh id.
    pub fn insert(
        &self,
        name: &str,
        imports: Vec<String>,
        got: GotTable,
        has_hlo: bool,
    ) -> Arc<LinkedIfunc> {
        let entry = Arc::new(LinkedIfunc {
            id: self.next_id.fetch_add(1, Ordering::Relaxed) as u32,
            name: name.to_string(),
            imports,
            got,
            has_hlo,
        });
        if self.enabled.load(Ordering::Relaxed) {
            self.map.write().unwrap().insert(name.to_string(), entry.clone());
        }
        entry
    }

    /// Drop a type (deregistration / invalidation).
    pub fn invalidate(&self, name: &str) {
        self.map.write().unwrap().remove(name);
    }

    pub fn len(&self) -> usize {
        self.map.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn miss_then_hit() {
        let c = IfuncCache::new();
        assert!(c.lookup("x").is_none());
        c.insert("x", vec![], GotTable::empty(), false);
        assert!(c.lookup("x").is_some());
        assert_eq!(c.hits.load(Ordering::Relaxed), 1);
        assert_eq!(c.misses.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn disabled_cache_never_hits() {
        let c = IfuncCache::new();
        c.set_enabled(false);
        c.insert("x", vec![], GotTable::empty(), false);
        assert!(c.lookup("x").is_none());
    }

    #[test]
    fn ids_are_unique() {
        let c = IfuncCache::new();
        let a = c.insert("a", vec![], GotTable::empty(), false);
        let b = c.insert("b", vec![], GotTable::empty(), false);
        assert_ne!(a.id, b.id);
    }

    #[test]
    fn invalidate_removes() {
        let c = IfuncCache::new();
        c.insert("x", vec![], GotTable::empty(), false);
        c.invalidate("x");
        assert!(c.lookup("x").is_none());
    }
}
