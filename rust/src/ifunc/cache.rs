//! Auto-registration code cache — the hash table of §3.4.
//!
//! "the `ucp_poll_ifunc` routine uses the ifunc's name provided by the
//! message header to attempt the auto-registration of any first-seen ifunc
//! type. If the corresponding library is found and loaded successfully,
//! the UCX runtime will patch the alternative GOT pointer ... and store
//! the related information in a hash table for subsequent messages of the
//! same type."
//!
//! A cache entry holds the reconstructed GOT (name-resolved bindings in
//! slot order), the import list it was resolved for, the **compiled
//! program** lowered from the verified code section (so repeat injections
//! skip the bytecode verifier *and* the threaded-dispatch compiler), a
//! fingerprint of the code bytes the program was verified from, and
//! whether the ifunc's HLO artifact has been handed to the PJRT runtime.
//! The entry id is what gets *patched into the message's GOT slot* before
//! invocation.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::vm::{CompiledProgram, GotTable, ProgramFacts};

use super::message::CodeImageRef;

/// A linked (auto-registered) ifunc type.
pub struct LinkedIfunc {
    /// Entry id — the value patched into the frame's GOT slot.
    pub id: u32,
    pub name: String,
    /// Import names the GOT was resolved against, in slot order.
    pub imports: Vec<String>,
    pub got: GotTable,
    /// The compiled program lowered from the verified code section this
    /// entry was linked against. Frames whose image matches execute it
    /// directly — verify *and* compile run once per (name, code) instead
    /// of per arrival.
    pub prog: CompiledProgram,
    /// Fingerprint of the code bytes `prog` was verified from. "The code
    /// can be modified anytime under the same ifunc name" (§3.4): a frame
    /// shipping different code or imports relinks and replaces this entry.
    pub code_fp: u64,
    /// Whether this type shipped an HLO artifact (compiled per-thread by
    /// the PJRT runtime; the engine re-ensures it on every arrival).
    pub has_hlo: bool,
    /// Static-analysis artifact for the same verified code — elision
    /// bounds, fuel floor, reachable host-call surface. Cached here so
    /// repeat injections skip the analysis pass along with verify and
    /// compile.
    pub facts: Arc<ProgramFacts>,
}

impl LinkedIfunc {
    /// Does this entry cover `image` — same import table, same code bytes?
    pub fn matches(&self, image: &CodeImageRef<'_>) -> bool {
        self.code_fp == image.fingerprint()
            && self.imports.iter().map(String::as_str).eq(image.imports.iter().copied())
    }
}

/// The §3.4 hash table, keyed by ifunc name. (Historically `IfuncCache`;
/// renamed when it started caching the executable program, not just
/// links — today that is the *compiled* threaded form.)
#[derive(Default)]
pub struct CodeCache {
    map: RwLock<HashMap<String, Arc<LinkedIfunc>>>,
    next_id: AtomicU64,
    pub hits: AtomicU64,
    pub misses: AtomicU64,
    /// If false, every message is relinked (and reverified) from scratch
    /// (ablation Abl B — quantifies what the paper's hash table saves).
    pub enabled: std::sync::atomic::AtomicBool,
}

impl CodeCache {
    pub fn new() -> Self {
        let c = CodeCache::default();
        c.enabled.store(true, Ordering::Relaxed);
        c
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// The execution-path hit test: an entry counts as a hit only if it
    /// was linked for the *same* import table and code bytes as `image`.
    /// A name collision with different code counts as a miss (the caller
    /// relinks + reverifies and [`CodeCache::insert`]s the replacement).
    pub fn lookup_matching(
        &self,
        name: &str,
        image: &CodeImageRef<'_>,
    ) -> Option<Arc<LinkedIfunc>> {
        if self.enabled.load(Ordering::Relaxed) {
            if let Some(entry) = self.map.read().unwrap().get(name) {
                if entry.matches(image) {
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    return Some(entry.clone());
                }
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        None
    }

    /// Insert (or replace) the entry for `name`; returns it with a fresh id.
    #[allow(clippy::too_many_arguments)]
    pub fn insert(
        &self,
        name: &str,
        imports: Vec<String>,
        got: GotTable,
        prog: CompiledProgram,
        code_fp: u64,
        has_hlo: bool,
        facts: Arc<ProgramFacts>,
    ) -> Arc<LinkedIfunc> {
        let entry = Arc::new(LinkedIfunc {
            id: self.next_id.fetch_add(1, Ordering::Relaxed) as u32,
            name: name.to_string(),
            imports,
            got,
            prog,
            code_fp,
            has_hlo,
            facts,
        });
        if self.enabled.load(Ordering::Relaxed) {
            self.map.write().unwrap().insert(name.to_string(), entry.clone());
        }
        entry
    }

    /// Drop a type (deregistration / invalidation).
    pub fn invalidate(&self, name: &str) {
        self.map.write().unwrap().remove(name);
    }

    pub fn len(&self) -> usize {
        self.map.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ifunc::message::CodeImage;

    /// Encoded code-section bytes; decode_ref them to drive the cache.
    fn sample_image() -> Vec<u8> {
        CodeImage { imports: vec![], vm_code: vec![0x5A; 8], hlo: vec![] }.encode()
    }

    fn insert_for(c: &CodeCache, name: &str, image_bytes: &[u8]) -> Arc<LinkedIfunc> {
        let (_, r) = CodeImage::decode_ref(image_bytes).unwrap();
        c.insert(
            name,
            vec![],
            GotTable::empty(),
            crate::vm::compile(Vec::new()),
            r.fingerprint(),
            false,
            Arc::new(crate::vm::analyze(&[])),
        )
    }

    #[test]
    fn miss_then_hit() {
        let bytes = sample_image();
        let (_, r) = CodeImage::decode_ref(&bytes).unwrap();
        let c = CodeCache::new();
        assert!(c.lookup_matching("x", &r).is_none());
        insert_for(&c, "x", &bytes);
        assert!(c.lookup_matching("x", &r).is_some());
        assert_eq!(c.hits.load(Ordering::Relaxed), 1);
        assert_eq!(c.misses.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn disabled_cache_never_hits() {
        let bytes = sample_image();
        let (_, r) = CodeImage::decode_ref(&bytes).unwrap();
        let c = CodeCache::new();
        c.set_enabled(false);
        insert_for(&c, "x", &bytes);
        assert!(c.lookup_matching("x", &r).is_none());
    }

    #[test]
    fn ids_are_unique() {
        let bytes = sample_image();
        let c = CodeCache::new();
        let a = insert_for(&c, "a", &bytes);
        let b = insert_for(&c, "b", &bytes);
        assert_ne!(a.id, b.id);
    }

    #[test]
    fn invalidate_removes() {
        let bytes = sample_image();
        let (_, r) = CodeImage::decode_ref(&bytes).unwrap();
        let c = CodeCache::new();
        insert_for(&c, "x", &bytes);
        c.invalidate("x");
        assert!(c.lookup_matching("x", &r).is_none());
    }

    #[test]
    fn lookup_matching_requires_same_imports_and_code() {
        let image = CodeImage {
            imports: vec!["counter_add".into()],
            vm_code: vec![1, 2, 3, 4, 5, 6, 7, 8],
            hlo: vec![],
        };
        let bytes = image.encode();
        let (_, r) = CodeImage::decode_ref(&bytes).unwrap();

        let c = CodeCache::new();
        assert!(c.lookup_matching("f", &r).is_none(), "empty cache misses");
        c.insert(
            "f",
            image.imports.clone(),
            GotTable::empty(),
            crate::vm::compile(Vec::new()),
            r.fingerprint(),
            false,
            Arc::new(crate::vm::analyze(&[])),
        );
        assert!(c.lookup_matching("f", &r).is_some(), "same image hits");

        // Same name, different code bytes: the "code modified under the
        // same name" case must miss (forces relink + reverify).
        let changed = CodeImage { vm_code: vec![9; 8], ..image.clone() };
        let cb = changed.encode();
        let (_, cr) = CodeImage::decode_ref(&cb).unwrap();
        assert!(c.lookup_matching("f", &cr).is_none());

        // Same code, different import table: also a miss.
        let reimported = CodeImage { imports: vec!["log".into()], ..image };
        let ib = reimported.encode();
        let (_, ir) = CodeImage::decode_ref(&ib).unwrap();
        assert!(c.lookup_matching("f", &ir).is_none());
    }

    #[test]
    fn lookup_matching_counts_stale_entry_as_miss() {
        let image =
            CodeImage { imports: vec![], vm_code: vec![0xAA; 8], hlo: vec![] };
        let bytes = image.encode();
        let (_, r) = CodeImage::decode_ref(&bytes).unwrap();
        let c = CodeCache::new();
        // fingerprint 0 ≠ r.fingerprint(): a stale entry under the name.
        c.insert(
            "f",
            vec![],
            GotTable::empty(),
            crate::vm::compile(Vec::new()),
            0,
            false,
            Arc::new(crate::vm::analyze(&[])),
        );
        assert!(c.lookup_matching("f", &r).is_none());
        assert_eq!(c.hits.load(Ordering::Relaxed), 0);
        assert_eq!(c.misses.load(Ordering::Relaxed), 1);
    }
}
