//! Stub of the `xla` crate (xla-rs PJRT bindings) — the one dependency of
//! this repo that cannot be vendored: real PJRT needs the multi-hundred-MB
//! `xla_extension` C++ distribution, which the offline build environment
//! does not ship.
//!
//! The stub is **API-compatible** with the call surface `runtime/mod.rs`
//! uses (`PjRtClient::cpu`, `HloModuleProto::parse_and_return_unverified_module`,
//! `XlaComputation::from_proto`, `compile`, `execute`, `Literal`), so
//! swapping the real crate in is mechanical:
//!
//! 1. add `xla = "..."` to `Cargo.toml`,
//! 2. delete this module and the `pub mod xla;` line in `lib.rs`,
//! 3. add `use xla;`-style extern imports where `use crate::xla;` appears.
//!
//! Every operation that would touch PJRT returns [`Error`], and
//! [`available`] reports `false`; callers that need real HLO execution
//! (the AOT-artifact integration tests, the `db_insert` /
//! `compute_offload` / `graph_analysis` examples) check it and skip.
//! Everything else — the ifunc transport, the TCVM, the AM baseline, the
//! coordinator — is pure Rust and unaffected.

use std::fmt;

/// Whether a real PJRT backend is linked into this build.
pub const fn available() -> bool {
    false
}

const UNAVAILABLE: &str =
    "PJRT backend unavailable: this build uses the in-tree xla stub (see rust/src/xla.rs)";

/// Error type mirroring `xla::Error`.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn unavailable() -> Self {
        Error(UNAVAILABLE.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// A parsed HLO module (stub: parsing always fails).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn parse_and_return_unverified_module(_hlo_text: &[u8]) -> Result<Self, Error> {
        Err(Error::unavailable())
    }
}

/// An XLA computation built from a proto.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// A host tensor (stub: carries no data).
#[derive(Debug)]
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Err(Error::unavailable())
    }

    pub fn to_tuple1(&self) -> Result<Literal, Error> {
        Err(Error::unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(Error::unavailable())
    }
}

/// A device buffer handle returned by `execute`.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::unavailable())
    }
}

/// A compiled executable (stub: can never be constructed through a real
/// compile, but the type must exist for the cache signatures).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::unavailable())
    }
}

/// The PJRT client. `cpu()` succeeds so the per-thread runtime can boot
/// and serve cache queries; only compilation/execution error out.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self, Error> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        assert!(!available());
        assert!(HloModuleProto::parse_and_return_unverified_module(b"HloModule m").is_err());
        let client = PjRtClient::cpu().unwrap();
        let comp = XlaComputation::from_proto(&HloModuleProto);
        let err = client.compile(&comp).unwrap_err();
        assert!(err.to_string().contains("unavailable"));
    }
}
