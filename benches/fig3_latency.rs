//! Fig. 3 — ping-pong one-way latency, ifunc vs UCX AM (paper §4.3).
//!
//! Sweeps payload sizes 1 B .. 1 MB over the CX-6-calibrated wire model
//! and prints the paper-style series: latency per transport, ifunc
//! latency reduction vs AM, and the crossover point.
//!
//! Paper shape to reproduce: ifunc up to ~42% slower at small payloads
//! (code bytes + clear_cache dominate), crossover between 8 KB and 16 KB,
//! ~35% latency reduction at 1 MB (AM pays rendezvous round-trips and
//! pipelined GET overheads; the ifunc is one PUT).
//!
//! Run: `cargo bench --bench fig3_latency` (QUICK=1 for a CI smoke run).

use two_chains::bench::harness::{BenchConfig, BenchPair};
use two_chains::bench::{latency, report};

fn main() {
    let quick = std::env::var("QUICK").is_ok_and(|v| !v.is_empty() && v != "0");
    let cfg = if quick {
        BenchConfig { sizes: vec![1, 4096, 65536], pingpong_iters: 30, ..BenchConfig::quick() }
    } else {
        BenchConfig::default()
    };
    eprintln!(
        "fig3: sweeping {} sizes, {} iters each (wire model {})",
        cfg.sizes.len(),
        cfg.pingpong_iters,
        if cfg.wire.enabled { "on: CX-6" } else { "off" }
    );

    let mut series = Vec::new();
    for &size in &cfg.sizes {
        let pair = BenchPair::new(cfg.clone()).expect("bench pair");
        let ifunc =
            latency::ifunc_pingpong(&pair, size, cfg.pingpong_iters).expect("ifunc pingpong");
        let am = latency::am_pingpong(&pair, size, cfg.pingpong_iters).expect("am pingpong");
        series.push(report::SeriesPoint { size, ifunc, am });
        eprint!(".");
    }
    eprintln!();
    report::print_series("Fig. 3 — one-way latency, ifunc vs UCX AM", "ns", &series, true);
    println!("{}", report::series_json("fig3", &series));
}
