//! Ablations (DESIGN.md experiment index, Abl A–L):
//!
//! * **A** — coherent vs non-coherent I-cache: the paper blames
//!   `clear_cache` for the small-payload loss and lists a coherent-I-cache
//!   machine as future work (§4.4/§5.1); this runs it.
//! * **B** — auto-registration cache off: every message pays the full
//!   relink (what the §3.4 hash table saves).
//! * **C** — AM rendezvous threshold (`UCX_RNDV_THRESH`) sensitivity: the
//!   position of the AM throughput *step*.
//! * **D** — code-section size: flush + verify scale with shipped code
//!   ("the code sent in the ifunc messages dominate the message size").
//! * **E** — delivery transport: RDMA-PUT rings (§3) vs AM send-receive
//!   (§5.1), driven through the *identical* cluster harness
//!   (leader + worker + dispatcher + reply credits) so only the
//!   `IfuncTransport` impl differs.
//! * **F** — batched delivery: `send_batch` (one coalesced credit
//!   reservation + one flush per 32-frame chunk) vs frame-at-a-time
//!   (send + flush per frame), on both transports over the same workload.
//! * **G** — reply streaming: big-record `invoke_get` with chunked
//!   multi-frame replies (`stream_replies: true`) vs the old inline-cap
//!   protocol (`stream_replies: false`), which *overflows* — ships no
//!   payload at all — past 64 KiB. The old column is a floor: it prices
//!   failing to return the record.
//! * **H** — intra-node transport: ring vs AM vs shm through the
//!   identical cluster harness, over small frames (delivery-dominated)
//!   and 1 MiB streamed gets (reply-stream-dominated). The shm column is
//!   the colocated fast path: no NIC engine, no wire model, no
//!   completion waits — its delta against ring prices the whole emulated
//!   fabric.
//! * **I** — collective invocation: `invoke_all` scatter-gather (one
//!   fan-out posting every link before any flush, replies merged at the
//!   leader) vs a leader-side loop of sequential `invoke_one` calls, over
//!   2/4/8 workers on every transport. The speedup column is what
//!   overlapping the per-link transfers buys — it should grow with the
//!   worker count.
//! * **J** — VM execution engine: reference match-loop
//!   (`vm::run_reference`) vs pre-compiled threaded dispatch
//!   (`vm::compile_unfused`) vs threaded + superinstruction fusion
//!   (`vm::compile`, the production path) on the counter / checksum /
//!   graph-filter bodies; plus AM delivery copy-on-execute vs the
//!   zero-copy execute-in-place path, in frames/s.
//! * **K** — concurrent serve front-end: 1/16/256 pipelined client
//!   sessions pushing inserts through one `Frontend`, cross-client
//!   coalescing on (same-worker ops merged into `try_invoke_batch`
//!   windows) vs off (each op an `invoke_one` round trip on its
//!   client's thread), per transport. The speedup column is what
//!   coalescing buys once clients contend for the same worker links —
//!   it should cross 1x somewhere between 1 and 16 clients.
//! * **L** — mesh forwarding: a two-stage pipeline driven either by
//!   leader relay (invoke stage 1, collect its result at the leader,
//!   reassemble a frame around it, invoke stage 2 — two full leader
//!   round trips per pipeline) or by one `forward`-chaining invocation
//!   whose intermediate result hops worker→worker over the mesh and
//!   never touches the leader. 2/4/8 workers on every transport; the
//!   speedup column is what cutting the leader out of the datapath buys.
//! * **M** — abstract-interpretation pass: the production fused engine
//!   with every dynamic check in place (`vm::compile`) vs the same body
//!   compiled against its `ProgramFacts` (`vm::compile_analyzed` —
//!   proven-in-bounds memory ops lowered to unchecked handlers behind
//!   entry guards, provably-bounded programs skipping the per-block
//!   fuel check), per body. The elided column counts the memory ops the
//!   analysis proved safe.
//!
//! Run: `cargo bench --bench ablations` (QUICK=1 for a smoke run;
//! ABL=E,H runs only the named ablations — CI's bench smoke uses
//! ABL=H,I,J,K,L,M).

use std::time::{Duration, Instant};

use two_chains::bench::harness::{BenchConfig, BenchPair};
use two_chains::bench::{latency, report, throughput};
use two_chains::coordinator::{
    Cluster, ClusterConfig, GetIfunc, InsertIfunc, Target, TransportKind,
};
use two_chains::ifunc::builtin::CounterIfunc;
use two_chains::ifunc::icache::IcacheConfig;
use two_chains::ifunc::SourceArgs;
use two_chains::ucp::AmParams;

fn lat_series(cfg: &BenchConfig) -> Vec<report::SeriesPoint> {
    cfg.sizes
        .iter()
        .map(|&size| {
            let pair = BenchPair::new(cfg.clone()).expect("pair");
            let ifunc = latency::ifunc_pingpong(&pair, size, cfg.pingpong_iters).unwrap();
            let am = latency::am_pingpong(&pair, size, cfg.pingpong_iters).unwrap();
            eprint!(".");
            report::SeriesPoint { size, ifunc, am }
        })
        .collect()
}

fn tput_series(cfg: &BenchConfig) -> Vec<report::SeriesPoint> {
    cfg.sizes
        .iter()
        .map(|&size| {
            let msgs = cfg.msgs_per_size.min((64 << 20) / size.max(1)).max(50);
            let pair = BenchPair::new(cfg.clone()).expect("pair");
            let ifunc = throughput::ifunc_throughput(&pair, size, msgs).unwrap();
            let am = throughput::am_throughput(&pair, size, msgs).unwrap();
            eprint!(".");
            report::SeriesPoint { size, ifunc, am }
        })
        .collect()
}

/// Messages/second pushing `msgs` counter frames of `size` payload bytes
/// through a one-worker cluster on the given transport, ending with a
/// reply-credit barrier. Everything except the `IfuncTransport` impl is
/// shared, so the delta is the transport itself (in-place ring execution
/// vs AM delivery's copy-on-execute + progress-loop dispatch).
fn cluster_throughput(
    base: &BenchConfig,
    transport: TransportKind,
    size: usize,
    msgs: usize,
) -> f64 {
    let cluster = Cluster::launch(
        ClusterConfig::builder()
            .workers(1)
            .transport(transport)
            .wire(base.wire)
            .build()
            .expect("config"),
        |_, ctx, _| {
            ctx.library_dir().install(Box::new(CounterIfunc::default()));
        },
    )
    .expect("cluster");
    cluster.leader.library_dir().install(Box::new(CounterIfunc::default()));
    let d = cluster.dispatcher();
    let h = d.register("counter").expect("register");
    let msg = h.msg_create(&SourceArgs::bytes(vec![0u8; size])).expect("msg");
    let t0 = Instant::now();
    for _ in 0..msgs {
        d.send(Target::Worker(0), &msg).expect("send");
    }
    d.barrier().expect("barrier");
    let dt = t0.elapsed().as_secs_f64();
    assert_eq!(d.total_executed(), msgs as u64);
    cluster.shutdown().expect("shutdown");
    msgs as f64 / dt
}

/// Abl F workload: completed delivery of `msgs` frames in chunks of
/// `batch`. `batch == 1` is frame-at-a-time (`send` + flush per
/// frame); `batch > 1` goes through `send_batch` — one coalesced
/// credit reservation + one flush per chunk on the ring, back-to-back
/// posts + one flush over AM — so the delta is exactly what batching
/// amortizes (per-frame completion waits and capacity checks).
fn cluster_batched_throughput(
    base: &BenchConfig,
    transport: TransportKind,
    size: usize,
    msgs: usize,
    batch: usize,
) -> f64 {
    let cluster = Cluster::launch(
        ClusterConfig::builder()
            .workers(1)
            .transport(transport)
            .wire(base.wire)
            .build()
            .expect("config"),
        |_, ctx, _| {
            ctx.library_dir().install(Box::new(CounterIfunc::default()));
        },
    )
    .expect("cluster");
    cluster.leader.library_dir().install(Box::new(CounterIfunc::default()));
    let d = cluster.dispatcher();
    let h = d.register("counter").expect("register");
    let msg = h.msg_create(&SourceArgs::bytes(vec![0u8; size])).expect("msg");
    let frames: Vec<_> = (0..batch).map(|_| msg.clone()).collect();
    let t0 = Instant::now();
    let mut left = msgs;
    while left > 0 {
        let take = left.min(batch);
        // A 1-frame batch degenerates to send + flush, so the two
        // modes differ only in chunking.
        d.send_batch(Target::Worker(0), &frames[..take]).expect("send_batch");
        left -= take;
    }
    d.barrier().expect("barrier");
    let dt = t0.elapsed().as_secs_f64();
    assert_eq!(d.total_executed(), msgs as u64);
    cluster.shutdown().expect("shutdown");
    msgs as f64 / dt
}

/// Abl G workload: `gets` big-record lookups against one worker, with
/// replies either chunk-streamed (`stream: true` — the record actually
/// comes back) or capped at one frame (`stream: false` — past 64 KiB the
/// reply overflows with r0 only). Returns gets/second.
fn cluster_get_throughput(
    base: &BenchConfig,
    transport: TransportKind,
    record_bytes: usize,
    stream: bool,
    gets: usize,
) -> f64 {
    let cluster = Cluster::launch(
        ClusterConfig::builder()
            .workers(1)
            .transport(transport)
            .stream_replies(stream)
            .wire(base.wire)
            .build()
            .expect("config"),
        |_, _, _| {},
    )
    .expect("cluster");
    cluster.leader.library_dir().install(Box::new(InsertIfunc));
    cluster.leader.library_dir().install(Box::new(GetIfunc));
    let d = cluster.dispatcher();
    let h_ins = d.register("insert").expect("register");
    let h_get = d.register("get").expect("register");
    let record: Vec<f32> = (0..record_bytes / 4).map(|i| i as f32).collect();
    let key = 7u64;
    d.send(Target::Worker(0), &h_ins.msg_create(&InsertIfunc::args(key, &record)).expect("msg"))
        .expect("insert");
    d.barrier().expect("barrier");
    let get = h_get.msg_create(&GetIfunc::args(key)).expect("msg");
    let t0 = Instant::now();
    for _ in 0..gets {
        let (reply, data) = d.fetch(Target::Worker(0), &get).expect("fetch");
        let streamed_back = reply.ok() && data.len() == record_bytes / 4;
        let overflowed = reply.overflowed() && data.is_empty();
        assert!(
            if stream || record_bytes <= 64 << 10 { streamed_back } else { overflowed },
            "unexpected reply shape (stream={stream}, {record_bytes}B)"
        );
    }
    let dt = t0.elapsed().as_secs_f64();
    cluster.shutdown().expect("shutdown");
    gets as f64 / dt
}

/// Abl I workload: `rounds` full-cluster invocation rounds against
/// `workers` workers — either one `invoke_all` per round (scatter-gather:
/// the fan-out posts every link before any flush, so per-link transfers
/// overlap and the merged wait collects replies as they land) or a
/// leader-side loop of sequential `invoke_one` calls (each round-trips
/// one worker before touching the next). Returns invocations/second.
fn collective_throughput(
    base: &BenchConfig,
    transport: TransportKind,
    workers: usize,
    scatter: bool,
    rounds: usize,
) -> f64 {
    let cluster = Cluster::launch(
        ClusterConfig::builder()
            .workers(workers)
            .transport(transport)
            .wire(base.wire)
            .build()
            .expect("config"),
        |_, ctx, _| {
            ctx.library_dir().install(Box::new(CounterIfunc::default()));
        },
    )
    .expect("cluster");
    cluster.leader.library_dir().install(Box::new(CounterIfunc::default()));
    let d = cluster.dispatcher();
    let h = d.register("counter").expect("register");
    let msg = h.msg_create(&SourceArgs::bytes(vec![0u8; 64])).expect("msg");
    let t0 = Instant::now();
    for _ in 0..rounds {
        if scatter {
            let merged = d.invoke_all(&msg).expect("invoke_all").wait().expect("wait");
            assert!(merged.all_ok());
        } else {
            for w in 0..workers {
                assert!(d.invoke_one(Target::Worker(w), &msg).expect("invoke_one").ok());
            }
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    assert_eq!(d.total_executed(), (rounds * workers) as u64);
    cluster.shutdown().expect("shutdown");
    (rounds * workers) as f64 / dt
}

/// Abl K workload: `clients` concurrent sessions, each keeping a
/// self-regulated window of pipelined inserts in flight against a
/// 4-worker cluster through one serve front-end. `coalesce: true` is the
/// production path (per-worker queues drained into `try_invoke_batch`
/// windows, one credit reservation + one flush per batch across
/// clients); `coalesce: false` dispatches each op as a blocking
/// `invoke_one` on the submitting client's thread. Returns requests/s.
fn serve_throughput(
    base: &BenchConfig,
    transport: TransportKind,
    clients: usize,
    coalesce: bool,
    ops_per_client: usize,
) -> f64 {
    use std::sync::Arc;
    use two_chains::coordinator::{Frontend, FrontendConfig};
    use two_chains::util::Json;

    let cluster = Arc::new(
        Cluster::launch(
            ClusterConfig::builder()
                .workers(4)
                .transport(transport)
                .wire(base.wire)
                .build()
                .expect("config"),
            |_, _, _| {},
        )
        .expect("cluster"),
    );
    let frontend = Arc::new(
        Frontend::launch(
            cluster.clone(),
            FrontendConfig {
                max_clients: clients.max(64),
                // Headroom so admission control never sheds: the table
                // prices the dispatch path, not overload behaviour.
                queue_high_water: 1 << 20,
                coalesce,
                ..FrontendConfig::default()
            },
        )
        .expect("frontend"),
    );

    let t0 = Instant::now();
    let threads: Vec<_> = (0..clients)
        .map(|c| {
            let fe = frontend.clone();
            std::thread::spawn(move || {
                let (session, responses) = fe.session().expect("session");
                let mut sent = 0usize;
                let mut got = 0usize;
                let pump = |responses: &two_chains::coordinator::SessionReceiver,
                            got: &mut usize| {
                    let r = responses.recv_timeout(Duration::from_secs(60)).expect("reply");
                    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
                    *got += 1;
                };
                for i in 0..ops_per_client {
                    while sent - got >= 8 {
                        pump(&responses, &mut got);
                    }
                    // Keys stride across all four workers.
                    let key = (c * ops_per_client + i) as u64;
                    session.submit(&format!(
                        "{{\"cmd\":\"insert\",\"key\":{key},\"data\":[1.0,2.0,3.0,4.0]}}"
                    ));
                    sent += 1;
                }
                while got < sent {
                    pump(&responses, &mut got);
                }
            })
        })
        .collect();
    for t in threads {
        t.join().expect("client thread");
    }
    let dt = t0.elapsed().as_secs_f64();

    Arc::try_unwrap(frontend).ok().expect("sessions closed").shutdown();
    Arc::try_unwrap(cluster).ok().expect("frontend gone").shutdown().expect("shutdown");
    (clients * ops_per_client) as f64 / dt
}

/// Abl L workload: `rounds` two-stage pipelines — stage 1 on worker `w`,
/// stage 2 on worker `(w + 1) % workers`, rotating `w` each round.
/// `mesh: false` is leader relay: invoke stage 1, wait for its result at
/// the leader, reassemble a frame around it, invoke stage 2 — two full
/// leader round trips plus a reassembly per pipeline. `mesh: true` ships
/// one `HopIfunc` invocation whose first stage `forward`s the frame to
/// the peer over the worker mesh, so the intermediate result never
/// touches the leader and only the final hop replies. Returns
/// pipelines/second.
fn pipeline_throughput(
    base: &BenchConfig,
    transport: TransportKind,
    workers: usize,
    mesh: bool,
    rounds: usize,
) -> f64 {
    use two_chains::ifunc::builtin::HopIfunc;
    let cluster = Cluster::launch(
        ClusterConfig::builder()
            .workers(workers)
            .transport(transport)
            .mesh(mesh)
            // Keep the 8-worker mesh (n·(n−1) peer rings) cheap to map.
            .ring_bytes(1 << 20)
            .wire(base.wire)
            .build()
            .expect("config"),
        |_, ctx, _| {
            ctx.library_dir().install(Box::new(HopIfunc));
        },
    )
    .expect("cluster");
    cluster.leader.library_dir().install(Box::new(HopIfunc));
    let d = cluster.dispatcher();
    let h = d.register("hop").expect("register");
    let data = vec![0x5Au8; 64];
    // Mesh arm: one pre-assembled frame per start worker, each naming its
    // ring neighbour as the chain's second stage.
    let mesh_msgs: Vec<_> = (0..workers)
        .map(|w| {
            h.msg_create(&SourceArgs::bytes(HopIfunc::payload(&[(w + 1) % workers], &data)))
                .expect("msg")
        })
        .collect();
    // Relay arm, stage 1: a chain-of-one that just replies with its data.
    let stage1 =
        h.msg_create(&SourceArgs::bytes(HopIfunc::payload(&[], &data))).expect("msg");
    let t0 = Instant::now();
    for round in 0..rounds {
        let w = round % workers;
        if mesh {
            let reply = d
                .invoke_begin(Target::Worker(w), &mesh_msgs[w])
                .expect("invoke")
                .wait()
                .expect("wait");
            assert!(reply.ok());
        } else {
            let r1 = d.invoke_one(Target::Worker(w), &stage1).expect("stage 1");
            assert!(r1.ok());
            // The leader reassembles stage 1's output into the stage 2
            // frame — the relay cost the mesh arm never pays.
            let stage2 = h
                .msg_create(&SourceArgs::bytes(HopIfunc::payload(&[], &r1.payload)))
                .expect("msg");
            let r2 =
                d.invoke_one(Target::Worker((w + 1) % workers), &stage2).expect("stage 2");
            assert!(r2.ok());
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    assert_eq!(d.total_executed(), (rounds * 2) as u64);
    cluster.shutdown().expect("shutdown");
    rounds as f64 / dt
}

fn main() {
    let quick = std::env::var("QUICK").is_ok_and(|v| !v.is_empty() && v != "0");
    // ABL=E,H (letters, any separator) restricts the run to the named
    // ablations; unset runs everything.
    let only: Option<Vec<char>> = std::env::var("ABL").ok().map(|v| {
        v.chars()
            .filter(char::is_ascii_alphabetic)
            .map(|c| c.to_ascii_uppercase())
            .collect()
    });
    let run = |letter: char| only.as_ref().is_none_or(|s| s.contains(&letter));
    let base = BenchConfig {
        sizes: if quick {
            vec![64, 8192]
        } else {
            vec![64, 1024, 4096, 8192, 65536, 1 << 20]
        },
        pingpong_iters: if quick { 20 } else { 100 },
        msgs_per_size: if quick { 100 } else { 400 },
        ..BenchConfig::default()
    };

    // Abl A — I-cache coherence.
    if run('A') {
        for (label, icache) in [
            ("non-coherent I-cache (paper testbed)", IcacheConfig::non_coherent()),
            ("coherent I-cache (paper §5.1 future work)", IcacheConfig::coherent()),
        ] {
            let cfg = BenchConfig { icache, ..base.clone() };
            let s = lat_series(&cfg);
            report::print_series(&format!("Abl A — latency, {label}"), "ns", &s, true);
        }
    }

    // Abl B — auto-registration cache.
    if run('B') {
        for (label, cache) in [("cache on (paper)", true), ("cache off", false)] {
            let cfg = BenchConfig { cache_enabled: cache, ..base.clone() };
            let s = lat_series(&cfg);
            report::print_series(&format!("Abl B — latency, {label}"), "ns", &s, true);
        }
    }

    // Abl C — rendezvous threshold.
    if run('C') {
        for thresh in [1024usize, 2000, 8192, 16384] {
            let cfg = BenchConfig {
                am: AmParams { rndv_threshold: thresh, ..base.am },
                ..base.clone()
            };
            let s = tput_series(&cfg);
            report::print_series(
                &format!("Abl C — throughput, UCX_RNDV_THRESH={thresh}"),
                "msg/s",
                &s,
                false,
            );
        }
    }

    // Abl D — shipped-code size.
    if run('D') {
        for pad in [0usize, 64, 512] {
            let cfg = BenchConfig { code_pad: pad, ..base.clone() };
            let s = lat_series(&cfg);
            report::print_series(
                &format!("Abl D — latency, +{pad} padding instrs (+{} code bytes)", pad * 8),
                "ns",
                &s,
                true,
            );
        }
    }

    // Abl E — delivery transport through the identical cluster harness.
    // SeriesPoint's `ifunc` column = ring transport, `am` column = ifuncs
    // over AM (both run the same injected counter through the dispatcher).
    if run('E') {
        let s: Vec<report::SeriesPoint> = base
            .sizes
            .iter()
            .map(|&size| {
                let msgs = base.msgs_per_size.min((64 << 20) / size.max(1)).max(50);
                let ring = cluster_throughput(&base, TransportKind::Ring, size, msgs);
                let am = cluster_throughput(&base, TransportKind::Am, size, msgs);
                eprint!(".");
                report::SeriesPoint { size, ifunc: ring, am }
            })
            .collect();
        report::print_series(
            "Abl E — cluster throughput, ring transport vs AM transport",
            "msg/s",
            &s,
            false,
        );
    }

    // Abl F — batched vs frame-at-a-time delivery, per transport, on the
    // identical workload. Column mapping (same trick as Abl E): `ifunc`
    // column = send_batch in chunks of 32, `AM` column = chunks of 1
    // (send + flush per frame) — so a positive "ifunc vs AM" % is the
    // batching win.
    if run('F') {
        for transport in [TransportKind::Ring, TransportKind::Am] {
            let s: Vec<report::SeriesPoint> = base
                .sizes
                .iter()
                .map(|&size| {
                    let msgs = base.msgs_per_size.min((64 << 20) / size.max(1)).max(50);
                    let batched = cluster_batched_throughput(&base, transport, size, msgs, 32);
                    let single = cluster_batched_throughput(&base, transport, size, msgs, 1);
                    eprint!(".");
                    report::SeriesPoint { size, ifunc: batched, am: single }
                })
                .collect();
            report::print_series(
                &format!(
                    "Abl F — {} transport: batched send_batch (ifunc col) vs \
                     frame-at-a-time (AM col)",
                    transport.label()
                ),
                "msg/s",
                &s,
                false,
            );
        }
    }

    // Abl G — reply streaming vs the old inline cap, per transport, over
    // record sizes straddling the 64 KiB chunk boundary. Column mapping
    // (same trick as Abl E/F): `ifunc` column = streamed chunked replies
    // (the record round-trips), `AM` column = stream_replies: false (past
    // 64 KiB the reply overflows and carries nothing — the old protocol's
    // price for *refusing* the record, shown for scale).
    let record_sizes: &[usize] = if quick {
        &[64 << 10, 256 << 10]
    } else {
        &[64 << 10, 256 << 10, 1 << 20]
    };
    if run('G') {
        for transport in [TransportKind::Ring, TransportKind::Am] {
            let s: Vec<report::SeriesPoint> = record_sizes
                .iter()
                .map(|&size| {
                    let gets = if quick { 30 } else { 150 };
                    let streamed = cluster_get_throughput(&base, transport, size, true, gets);
                    let capped = cluster_get_throughput(&base, transport, size, false, gets);
                    eprint!(".");
                    report::SeriesPoint { size, ifunc: streamed, am: capped }
                })
                .collect();
            report::print_series(
                &format!(
                    "Abl G — {} transport: streamed big-record invoke_get (ifunc col) vs \
                     stream_replies: false overflow (AM col)",
                    transport.label()
                ),
                "get/s",
                &s,
                false,
            );
        }
    }

    // Abl H — intra-node transport: ring vs AM vs shm on the identical
    // cluster harness. Two regimes: small fire-and-forget frames (the
    // per-delivery overhead is the whole story) and 1 MiB streamed
    // invoke_get (the reply chunk stream dominates). The final column is
    // the shm speedup over the fabric ring — the price of the emulated
    // PUT path that colocated workers no longer pay.
    if run('H') {
        let sizes: &[usize] = if quick { &[64, 8192] } else { &[64, 1024, 8192, 65536] };
        println!("\n== Abl H — cluster throughput by transport (small frames, msg/s) ==");
        println!(
            "{:>10}  {:>12}  {:>12}  {:>12}  {:>12}",
            "size", "ring", "am", "shm", "shm vs ring"
        );
        for &size in sizes {
            let msgs = base.msgs_per_size.min((64 << 20) / size.max(1)).max(50);
            let ring = cluster_throughput(&base, TransportKind::Ring, size, msgs);
            let am = cluster_throughput(&base, TransportKind::Am, size, msgs);
            let shm = cluster_throughput(&base, TransportKind::Shm, size, msgs);
            println!(
                "{size:>10}  {ring:>12.0}  {am:>12.0}  {shm:>12.0}  {:>+11.1}%",
                (shm - ring) / ring * 100.0
            );
        }
        let get_sizes: &[usize] = if quick { &[1 << 20] } else { &[64 << 10, 1 << 20] };
        println!("\n== Abl H — streamed invoke_get by transport (get/s) ==");
        println!(
            "{:>10}  {:>12}  {:>12}  {:>12}  {:>12}",
            "record", "ring", "am", "shm", "shm vs ring"
        );
        for &bytes in get_sizes {
            let gets = if quick { 20 } else { 100 };
            let ring = cluster_get_throughput(&base, TransportKind::Ring, bytes, true, gets);
            let am = cluster_get_throughput(&base, TransportKind::Am, bytes, true, gets);
            let shm = cluster_get_throughput(&base, TransportKind::Shm, bytes, true, gets);
            println!(
                "{bytes:>10}  {ring:>12.2}  {am:>12.2}  {shm:>12.2}  {:>+11.1}%",
                (shm - ring) / ring * 100.0
            );
        }
    }

    // Abl I — collective scatter-gather vs the leader-side invoke loop,
    // over 2/4/8 workers on every transport. The loop pays one full
    // round trip per worker per round; the collective overlaps all of
    // them, so its speedup should grow with the worker count.
    if run('I') {
        let rounds = if quick { 50 } else { 400 };
        println!("\n== Abl I — collective invocation throughput (64B, invocations/s) ==");
        println!(
            "{:>10}  {:>8}  {:>14}  {:>14}  {:>10}",
            "transport", "workers", "scatter-gather", "leader loop", "speedup"
        );
        for transport in TransportKind::ALL {
            for workers in [2usize, 4, 8] {
                let sg = collective_throughput(&base, transport, workers, true, rounds);
                let looped = collective_throughput(&base, transport, workers, false, rounds);
                println!(
                    "{:>10}  {workers:>8}  {sg:>14.0}  {looped:>14.0}  {:>9.2}x",
                    transport.label(),
                    sg / looped
                );
            }
        }
    }

    // Abl J — VM execution engine. Same verified body through all three
    // engines: the reference match-loop, threaded dispatch without
    // fusion, and the production threaded+fusion form — isolating what
    // pre-resolved handlers vs superinstructions each buy per body.
    if run('J') {
        use two_chains::coordinator::FilterIfunc;
        use two_chains::ifunc::am_transport::{execute_am_frame, execute_am_frame_in_place};
        use two_chains::ifunc::builtin::ChecksumIfunc;
        use two_chains::ifunc::message::CodeImage;
        use two_chains::ifunc::{IfuncLibrary, Symbols, TargetArgs};
        use two_chains::vm;

        let syms = Symbols::with_builtins();
        // The filter body's import is a worker-store symbol; stub it with
        // a pure function so the column prices the VM, not the store.
        syms.table().install_fn("db_filter", |_, [bits, _, _, _]| Ok(bits));

        println!("\n== Abl J — VM engine per body (ns/op) ==");
        println!(
            "{:>14}  {:>6}  {:>12}  {:>12}  {:>16}  {:>10}",
            "body", "fused", "match-loop", "threaded", "threaded+fusion", "speedup"
        );
        let bodies: [(&str, CodeImage, usize, usize); 3] = [
            ("counter", CounterIfunc::default().code(), 64, if quick { 2_000 } else { 100_000 }),
            ("checksum", ChecksumIfunc.code(), 8192, if quick { 50 } else { 1_000 }),
            ("graph-filter", FilterIfunc.code(), 8, if quick { 2_000 } else { 100_000 }),
        ];
        for (name, image, paysize, iters) in bodies {
            let prog = vm::verify(&image.vm_code, image.imports.len()).expect("verify");
            let got = syms.table().resolve(&image.imports).expect("resolve");
            let unfused = vm::compile_unfused(prog.clone());
            let compiled = vm::compile(prog.clone());
            let cfg = vm::VmConfig::default();
            let mut payload = vec![1u8; paysize];

            let t0 = Instant::now();
            for _ in 0..iters {
                std::hint::black_box(
                    vm::run_reference(&prog, &got, &mut payload, &mut (), &cfg).unwrap(),
                );
            }
            let matchloop = t0.elapsed().as_nanos() as f64 / iters as f64;

            let t0 = Instant::now();
            for _ in 0..iters {
                std::hint::black_box(unfused.run(&got, &mut payload, &mut (), &cfg).unwrap());
            }
            let threaded = t0.elapsed().as_nanos() as f64 / iters as f64;

            let t0 = Instant::now();
            for _ in 0..iters {
                std::hint::black_box(compiled.run(&got, &mut payload, &mut (), &cfg).unwrap());
            }
            let fusion = t0.elapsed().as_nanos() as f64 / iters as f64;

            println!(
                "{name:>14}  {:>6}  {matchloop:>12.0}  {threaded:>12.0}  {fusion:>16.0}  {:>9.2}x",
                compiled.fused_pairs(),
                matchloop / fusion
            );
        }

        // AM delivery: copy-on-execute (one to_vec per frame, the old
        // receive path) vs execute-in-place on the persistent delivery
        // buffer (the path `set_am_handler_mut` now gives the adapter).
        use two_chains::fabric::{Fabric, WireConfig};
        use two_chains::ucp::{Context, ContextConfig};
        let f = Fabric::new(1, WireConfig::off());
        let ctx = Context::new(f.node(0), ContextConfig::default()).expect("ctx");
        ctx.library_dir().install(Box::new(CounterIfunc::default()));
        let h = ctx.register_ifunc("counter").expect("register");
        let msg = h.msg_create(&SourceArgs::bytes(vec![0u8; 64])).expect("msg");
        let ta = std::sync::Arc::new(std::sync::Mutex::new(TargetArgs::none()));
        let iters = if quick { 2_000 } else { 100_000 };

        let t0 = Instant::now();
        for _ in 0..iters {
            execute_am_frame(&ctx, msg.frame(), &ta).expect("copy execute");
        }
        let copy_fps = iters as f64 / t0.elapsed().as_secs_f64();

        let mut frame = msg.frame().to_vec();
        let t0 = Instant::now();
        for _ in 0..iters {
            execute_am_frame_in_place(&ctx, &mut frame, &ta).expect("in-place execute");
        }
        let zc_fps = iters as f64 / t0.elapsed().as_secs_f64();

        println!("\n== Abl J — AM execute: copy-on-execute vs in-place (64B counter frames/s) ==");
        println!(
            "{:>14}  {:>14}  {:>10}",
            "copy", "zero-copy", "speedup"
        );
        println!("{copy_fps:>14.0}  {zc_fps:>14.0}  {:>9.2}x", zc_fps / copy_fps);
    }

    // Abl K — the concurrent serve front-end. Same insert workload per
    // row; only the dispatch strategy changes. At 1 client, coalescing
    // is pure overhead (an extra queue hop and thread handoff per op);
    // as clients contend for the same four links, batching amortizes
    // credit reservations and flushes across clients and the speedup
    // column should cross 1x.
    if run('K') {
        let client_counts: &[usize] = if quick { &[1, 16] } else { &[1, 16, 256] };
        let total_ops = if quick { 2_000 } else { 20_000 };
        println!("\n== Abl K — serve front-end insert throughput (4 workers, req/s) ==");
        println!(
            "{:>10}  {:>8}  {:>12}  {:>12}  {:>10}",
            "transport", "clients", "coalesced", "direct", "speedup"
        );
        for transport in TransportKind::ALL {
            for &clients in client_counts {
                let ops = (total_ops / clients).max(8);
                let on = serve_throughput(&base, transport, clients, true, ops);
                let off = serve_throughput(&base, transport, clients, false, ops);
                println!(
                    "{:>10}  {clients:>8}  {on:>12.0}  {off:>12.0}  {:>9.2}x",
                    transport.label(),
                    on / off
                );
            }
        }
    }

    // Abl L — mesh forwarding vs leader relay on the same two-stage
    // pipeline. The relay arm pays two full leader round trips plus a
    // frame reassembly per pipeline; the mesh arm pays one round trip,
    // with the intermediate result hopping worker→worker. The speedup
    // prices cutting the leader out of the inter-stage datapath.
    if run('L') {
        let rounds = if quick { 50 } else { 400 };
        println!("\n== Abl L — two-stage pipeline throughput (64B, pipelines/s) ==");
        println!(
            "{:>10}  {:>8}  {:>14}  {:>14}  {:>10}",
            "transport", "workers", "mesh forward", "leader relay", "speedup"
        );
        for transport in TransportKind::ALL {
            for workers in [2usize, 4, 8] {
                let fwd = pipeline_throughput(&base, transport, workers, true, rounds);
                let relay = pipeline_throughput(&base, transport, workers, false, rounds);
                println!(
                    "{:>10}  {workers:>8}  {fwd:>14.0}  {relay:>14.0}  {:>9.2}x",
                    transport.label(),
                    fwd / relay
                );
            }
        }
    }

    // Abl M — what the analysis pass buys at execution time. Same
    // verified body, same fused threaded engine; the only difference is
    // whether the compiler consumed the ProgramFacts (unchecked memory
    // handlers behind entry guards + fuel-check skip for provably
    // bounded programs) or kept every dynamic check.
    if run('M') {
        use two_chains::coordinator::FilterIfunc;
        use two_chains::ifunc::builtin::ChecksumIfunc;
        use two_chains::ifunc::message::CodeImage;
        use two_chains::ifunc::{IfuncLibrary, Symbols};
        use two_chains::vm;

        let syms = Symbols::with_builtins();
        // Same stub as Abl J: price the VM, not the worker store.
        syms.table().install_fn("db_filter", |_, [bits, _, _, _]| Ok(bits));

        println!("\n== Abl M — analysis pass: checked vs elided compile (ns/op) ==");
        println!(
            "{:>14}  {:>7}  {:>9}  {:>12}  {:>12}  {:>10}",
            "body", "elided", "may-loop", "checked", "analyzed", "speedup"
        );
        let bodies: [(&str, CodeImage, usize, usize); 3] = [
            ("counter", CounterIfunc::default().code(), 64, if quick { 2_000 } else { 100_000 }),
            ("checksum", ChecksumIfunc.code(), 8192, if quick { 50 } else { 1_000 }),
            ("graph-filter", FilterIfunc.code(), 8, if quick { 2_000 } else { 100_000 }),
        ];
        for (name, image, paysize, iters) in bodies {
            let prog = vm::verify(&image.vm_code, image.imports.len()).expect("verify");
            let got = syms.table().resolve(&image.imports).expect("resolve");
            let facts = vm::analyze(&prog);
            let checked = vm::compile(prog.clone());
            let analyzed = vm::compile_analyzed(prog.clone(), &facts);
            let cfg = vm::VmConfig::default();
            let mut payload = vec![1u8; paysize];

            let t0 = Instant::now();
            for _ in 0..iters {
                std::hint::black_box(checked.run(&got, &mut payload, &mut (), &cfg).unwrap());
            }
            let checked_ns = t0.elapsed().as_nanos() as f64 / iters as f64;

            let t0 = Instant::now();
            for _ in 0..iters {
                std::hint::black_box(analyzed.run(&got, &mut payload, &mut (), &cfg).unwrap());
            }
            let analyzed_ns = t0.elapsed().as_nanos() as f64 / iters as f64;

            println!(
                "{name:>14}  {:>7}  {:>9}  {checked_ns:>12.0}  {analyzed_ns:>12.0}  {:>9.2}x",
                facts.elided_ops,
                facts.may_loop(),
                checked_ns / analyzed_ns
            );
        }
    }
}
