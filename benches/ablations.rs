//! Ablations (DESIGN.md experiment index, Abl A–D):
//!
//! * **A** — coherent vs non-coherent I-cache: the paper blames
//!   `clear_cache` for the small-payload loss and lists a coherent-I-cache
//!   machine as future work (§4.4/§5.1); this runs it.
//! * **B** — auto-registration cache off: every message pays the full
//!   relink (what the §3.4 hash table saves).
//! * **C** — AM rendezvous threshold (`UCX_RNDV_THRESH`) sensitivity: the
//!   position of the AM throughput *step*.
//! * **D** — code-section size: flush + verify scale with shipped code
//!   ("the code sent in the ifunc messages dominate the message size").
//!
//! Run: `cargo bench --bench ablations` (QUICK=1 for a smoke run).

use two_chains::bench::harness::{BenchConfig, BenchPair};
use two_chains::bench::{latency, report, throughput};
use two_chains::ifunc::icache::IcacheConfig;
use two_chains::ucp::AmParams;

fn lat_series(cfg: &BenchConfig) -> Vec<report::SeriesPoint> {
    cfg.sizes
        .iter()
        .map(|&size| {
            let pair = BenchPair::new(cfg.clone()).expect("pair");
            let ifunc = latency::ifunc_pingpong(&pair, size, cfg.pingpong_iters).unwrap();
            let am = latency::am_pingpong(&pair, size, cfg.pingpong_iters).unwrap();
            eprint!(".");
            report::SeriesPoint { size, ifunc, am }
        })
        .collect()
}

fn tput_series(cfg: &BenchConfig) -> Vec<report::SeriesPoint> {
    cfg.sizes
        .iter()
        .map(|&size| {
            let msgs = cfg.msgs_per_size.min((64 << 20) / size.max(1)).max(50);
            let pair = BenchPair::new(cfg.clone()).expect("pair");
            let ifunc = throughput::ifunc_throughput(&pair, size, msgs).unwrap();
            let am = throughput::am_throughput(&pair, size, msgs).unwrap();
            eprint!(".");
            report::SeriesPoint { size, ifunc, am }
        })
        .collect()
}

fn main() {
    let quick = std::env::var("QUICK").is_ok_and(|v| !v.is_empty() && v != "0");
    let base = BenchConfig {
        sizes: if quick {
            vec![64, 8192]
        } else {
            vec![64, 1024, 4096, 8192, 65536, 1 << 20]
        },
        pingpong_iters: if quick { 20 } else { 100 },
        msgs_per_size: if quick { 100 } else { 400 },
        ..BenchConfig::default()
    };

    // Abl A — I-cache coherence.
    for (label, icache) in [
        ("non-coherent I-cache (paper testbed)", IcacheConfig::non_coherent()),
        ("coherent I-cache (paper §5.1 future work)", IcacheConfig::coherent()),
    ] {
        let cfg = BenchConfig { icache, ..base.clone() };
        let s = lat_series(&cfg);
        report::print_series(&format!("Abl A — latency, {label}"), "ns", &s, true);
    }

    // Abl B — auto-registration cache.
    for (label, cache) in [("cache on (paper)", true), ("cache off", false)] {
        let cfg = BenchConfig { cache_enabled: cache, ..base.clone() };
        let s = lat_series(&cfg);
        report::print_series(&format!("Abl B — latency, {label}"), "ns", &s, true);
    }

    // Abl C — rendezvous threshold.
    for thresh in [1024usize, 2000, 8192, 16384] {
        let cfg = BenchConfig {
            am: AmParams { rndv_threshold: thresh, ..base.am },
            ..base.clone()
        };
        let s = tput_series(&cfg);
        report::print_series(
            &format!("Abl C — throughput, UCX_RNDV_THRESH={thresh}"),
            "msg/s",
            &s,
            false,
        );
    }

    // Abl D — shipped-code size.
    for pad in [0usize, 64, 512] {
        let cfg = BenchConfig { code_pad: pad, ..base.clone() };
        let s = lat_series(&cfg);
        report::print_series(
            &format!("Abl D — latency, +{pad} padding instrs (+{} code bytes)", pad * 8),
            "ns",
            &s,
            true,
        );
    }
}
