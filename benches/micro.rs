//! Component microbenchmarks — the profile behind the §Perf pass.
//!
//! Times each stage of the ifunc hot path in isolation (criterion is
//! unavailable offline; this uses a median-of-batches timer):
//! frame assembly, header decode, code-image decode, bytecode verify,
//! VM dispatch, GOT resolve, fabric put+flush, poll round trip.
//!
//! Run: `cargo bench --bench micro`. `QUICK=1` shrinks the batches for a
//! CI smoke run; `--json PATH` (or `MICRO_JSON=PATH`) additionally writes
//! the `bench::report::micro_json` report CI uploads as an artifact.

use std::time::Instant;

use two_chains::bench::report::{micro_json, MicroRow};
use two_chains::fabric::{Fabric, MemPerm, WireConfig};
use two_chains::ifunc::builtin::CounterIfunc;
use two_chains::ifunc::message::{CodeImage, Header, IfuncMsg};
use two_chains::ifunc::{IfuncLibrary, IfuncRing, SenderCursor, SourceArgs, TargetArgs};
use two_chains::ucp::{Context, ContextConfig, Worker};
use two_chains::vm;

/// Collects the median/best ns/op of every stage, for the JSON report.
struct Timer {
    quick: bool,
    rows: Vec<MicroRow>,
}

impl Timer {
    /// Median ns/op over `batches` batches of `per_batch` iterations.
    fn bench(&mut self, name: &str, batches: usize, per_batch: usize, mut f: impl FnMut()) {
        let (batches, per_batch) =
            if self.quick { (batches.min(5), per_batch.min(200)) } else { (batches, per_batch) };
        let mut times: Vec<f64> = (0..batches)
            .map(|_| {
                let t0 = Instant::now();
                for _ in 0..per_batch {
                    f();
                }
                t0.elapsed().as_nanos() as f64 / per_batch as f64
            })
            .collect();
        times.sort_by(f64::total_cmp);
        let med = times[times.len() / 2];
        let best = times[0];
        println!("{name:<44} {med:>12.0} ns/op   (best {best:>10.0})");
        self.rows.push(MicroRow { name: name.to_string(), median_ns: med, best_ns: best });
    }
}

/// Report path from `--json PATH` (after the `--` cargo passes through) or
/// the `MICRO_JSON` environment variable.
fn json_path() -> Option<std::path::PathBuf> {
    let args: Vec<String> = std::env::args().collect();
    if let Some(i) = args.iter().position(|a| a == "--json") {
        if let Some(p) = args.get(i + 1) {
            return Some(p.into());
        }
    }
    std::env::var_os("MICRO_JSON").map(Into::into)
}

fn main() {
    let quick = std::env::var("QUICK").is_ok_and(|v| !v.is_empty() && v != "0");
    let mut t = Timer { quick, rows: Vec::new() };
    println!("== component microbenchmarks (hot-path stages) ==\n");
    let lib = CounterIfunc::default();
    let code = lib.code();
    let args = SourceArgs::bytes(vec![7u8; 256]);

    // Source-side stages.
    t.bench("msg_create (assemble 256B payload frame)", 30, 2000, || {
        let msg = IfuncMsg::assemble_with("counter", &code, 256, Default::default(), |p| {
            p.copy_from_slice(args.as_bytes());
            Ok(256)
        })
        .unwrap();
        std::hint::black_box(msg);
    });

    let msg = IfuncMsg::assemble("counter", &code, args.as_bytes(), Default::default()).unwrap();
    t.bench("header decode + validate", 30, 20000, || {
        std::hint::black_box(Header::decode(msg.frame()).unwrap());
    });

    let h = Header::decode(msg.frame()).unwrap().unwrap();
    let code_bytes = &msg.frame()[h.code_offset as usize..(h.code_offset + h.code_len) as usize];
    t.bench("code-image decode", 30, 20000, || {
        std::hint::black_box(CodeImage::decode(code_bytes).unwrap());
    });

    let (_, image) = CodeImage::decode(code_bytes).unwrap();
    t.bench("bytecode verify (counter, 3 instrs)", 30, 20000, || {
        std::hint::black_box(vm::verify(&image.vm_code, image.imports.len()).unwrap());
    });

    let prog = vm::verify(&image.vm_code, image.imports.len()).unwrap();
    let syms = two_chains::ifunc::Symbols::with_builtins();
    let got = syms.table().resolve(&image.imports).unwrap();
    t.bench("GOT resolve (1 import)", 30, 20000, || {
        std::hint::black_box(syms.table().resolve(&image.imports).unwrap());
    });

    let cfg = vm::VmConfig::default();
    let mut payload = vec![0u8; 256];
    // Reference match-loop row (name predates the compiler — kept stable
    // so the committed baseline still matches).
    t.bench("VM run (counter body)", 30, 20000, || {
        std::hint::black_box(
            vm::run_reference(&prog, &got, &mut payload, &mut (), &cfg).unwrap(),
        );
    });

    // The production path: the same verified body, pre-compiled to
    // threaded handlers once (as the code cache stores it).
    let compiled = vm::compile(prog.clone());
    t.bench("VM run (counter body, compiled)", 30, 20000, || {
        std::hint::black_box(
            compiled.run(&got, &mut payload, &mut (), &cfg).unwrap(),
        );
    });

    // What the engine actually runs since the analysis pass: the same
    // body compiled against its ProgramFacts, with proven-in-bounds
    // memory ops lowered to unchecked handlers behind entry guards and
    // the per-block fuel check dropped for provably-bounded programs.
    let facts = vm::analyze(&prog);
    let analyzed = vm::compile_analyzed(prog.clone(), &facts);
    t.bench("VM run (counter body, analyzed)", 30, 20000, || {
        std::hint::black_box(
            analyzed.run(&got, &mut payload, &mut (), &cfg).unwrap(),
        );
    });

    // Fabric stages (wire model off: pure software path).
    let fabric = Fabric::new(2, WireConfig::off());
    let mr = fabric.node(1).register(1 << 20, MemPerm::RWX);
    let qp = fabric.connect(0, 1);
    for (label, size) in [("64B", 64usize), ("4KB", 4096), ("64KB", 65536)] {
        let data = vec![0xABu8; size];
        t.bench(&format!("fabric put_nbi+flush ({label})"), 20, 2000, || {
            qp.put_nbi(mr.rkey(), 0, &data).unwrap();
            qp.flush().unwrap();
        });
    }

    // Full poll round trip (send + poll execute), software-only.
    let src = Context::new(fabric.node(0), ContextConfig::default()).unwrap();
    let dst = Context::new(fabric.node(1), ContextConfig::default()).unwrap();
    src.library_dir().install(Box::new(CounterIfunc::default()));
    let ws = Worker::new(&src);
    let wd = Worker::new(&dst);
    let ep = ws.connect(&wd).unwrap();
    let mut ring = IfuncRing::new(&dst, 1 << 20).unwrap();
    let mut cursor = SenderCursor::new(ring.size());
    let handle = src.register_ifunc("counter").unwrap();
    let m = handle.msg_create(&SourceArgs::bytes(vec![0u8; 64])).unwrap();
    let mut targs = TargetArgs::none();
    t.bench("ifunc send+flush+poll+execute (64B)", 20, 2000, || {
        ep.ifunc_msg_send_cursor(&m, &mut cursor, ring.rkey()).unwrap();
        ep.flush().unwrap();
        dst.poll_ifunc_blocking(&mut ring, &mut targs).unwrap();
    });

    // Verified-program cache ablation: the row above hits the code cache
    // (link + vm::verify both skipped after the first frame); with the
    // cache disabled every arrival pays the full relink + reverify — the
    // delta is what caching the *verified program* saves per injection.
    dst.ifunc_cache().set_enabled(false);
    t.bench("ifunc send+flush+poll+execute (64B, cache off)", 20, 2000, || {
        ep.ifunc_msg_send_cursor(&m, &mut cursor, ring.rkey()).unwrap();
        ep.flush().unwrap();
        dst.poll_ifunc_blocking(&mut ring, &mut targs).unwrap();
    });
    dst.ifunc_cache().set_enabled(true);

    // Shm counterpart of the row above: the same frame, the same poll
    // loop, but delivery is a direct memcpy into the shared ring mapping
    // — no endpoint, no NIC engine, no completion wait. The delta against
    // the ring row is the whole emulated-fabric PUT path.
    {
        use two_chains::ifunc::{ConsumedCounter, ReplyRing, ShmTransport};
        let shm_ctx = Context::new(fabric.node(1), ContextConfig::default()).unwrap();
        shm_ctx.library_dir().install(Box::new(CounterIfunc::default()));
        let mut shm_ring = IfuncRing::new(&shm_ctx, 1 << 20).unwrap();
        let credit = shm_ctx.mem_map(64, MemPerm::RW);
        let replies = ReplyRing::new(&shm_ctx, None);
        let consumed = ConsumedCounter::new(&shm_ctx, None);
        let mut shm =
            ShmTransport::new(shm_ring.region(), credit.clone(), replies, consumed);
        let h_shm = shm_ctx.register_ifunc("counter").unwrap();
        let m_shm = h_shm.msg_create(&SourceArgs::bytes(vec![0u8; 64])).unwrap();
        let mut shm_targs = TargetArgs::none();
        use two_chains::ifunc::IfuncTransport;
        t.bench("ifunc shm memcpy+poll+execute (64B)", 20, 2000, || {
            shm.send_frame(&m_shm).unwrap();
            shm_ctx.poll_ifunc_blocking(&mut shm_ring, &mut shm_targs).unwrap();
            credit.store_u64_release(0, shm_ring.consumed_bytes).unwrap();
        });
    }

    // AM counterpart.
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;
    let hits = Arc::new(AtomicU64::new(0));
    let h2 = hits.clone();
    wd.set_am_handler(9, move |_, _| {
        h2.fetch_add(1, Ordering::Relaxed);
    });
    let data = vec![0u8; 64];
    t.bench("AM send+flush+progress (64B eager)", 20, 2000, || {
        let before = hits.load(Ordering::Relaxed);
        ep.am_send(9, &data).unwrap();
        ep.flush().unwrap();
        while hits.load(Ordering::Relaxed) == before {
            wd.progress();
        }
    });

    // Zero-copy ifunc-over-AM delivery: the frame executes in place in
    // the eager ring slot — no per-frame `to_vec` on the receive path.
    {
        use std::sync::Mutex;
        use two_chains::ifunc::am_transport::{ifunc_msg_send_am, install_am_ifunc};
        install_am_ifunc(&wd, Arc::new(Mutex::new(TargetArgs::none())));
        t.bench("AM send+flush+progress (64B eager, zero-copy)", 20, 2000, || {
            let before = dst.symbols().counter_value();
            ifunc_msg_send_am(&ep, &m).unwrap();
            ep.flush().unwrap();
            while dst.symbols().counter_value() == before {
                wd.progress();
            }
        });
    }

    // Pipelined invocation throughput: a one-worker cluster driven through
    // invoke_begin/PendingReply with a sliding window of outstanding
    // invocations. Window 1 is the old invoke-under-lock behavior (send,
    // wait, repeat); wider windows overlap frame delivery with reply
    // collection on the same link.
    {
        use std::collections::VecDeque;
        use two_chains::coordinator::{Cluster, ClusterConfig, Target, TransportKind};
        // Window 1/4/16 on the default ring transport (the PR 3 rows),
        // plus a window-16 shm row: the same pipelined workload on the
        // intra-node fast path.
        for (window, transport) in [
            (1usize, TransportKind::Ring),
            (4, TransportKind::Ring),
            (16, TransportKind::Ring),
            (16, TransportKind::Shm),
        ] {
            let cluster = Cluster::launch(
                ClusterConfig::builder()
                    .workers(1)
                    .max_inflight(window)
                    .transport(transport)
                    .build()
                    .expect("config"),
                |_, ctx, _| {
                    ctx.library_dir().install(Box::new(CounterIfunc::default()));
                },
            )
            .expect("cluster");
            cluster.leader.library_dir().install(Box::new(CounterIfunc::default()));
            let d = cluster.dispatcher();
            let h = d.register("counter").expect("register");
            let m = h.msg_create(&SourceArgs::bytes(vec![0u8; 64])).expect("msg");
            let iters = if quick { 300 } else { 3000 };
            let mut pending = VecDeque::new();
            let t0 = Instant::now();
            for _ in 0..iters {
                if pending.len() == window {
                    pending.pop_front().unwrap().wait().expect("reply");
                }
                pending.push_back(d.invoke_begin(Target::Worker(0), &m).expect("invoke_begin"));
            }
            while let Some(p) = pending.pop_front() {
                p.wait().expect("reply");
            }
            let ns = t0.elapsed().as_nanos() as f64 / iters as f64;
            // Row names for the ring rows predate the transport sweep —
            // keep them stable so the committed baseline still matches.
            let name = match transport {
                TransportKind::Ring => format!("pipelined invoke (window {window})"),
                other => format!("pipelined invoke (window {window}, {})", other.label()),
            };
            println!("{name:<44} {ns:>12.0} ns/op");
            t.rows.push(MicroRow { name, median_ns: ns, best_ns: ns });
            cluster.shutdown().expect("shutdown");
        }
    }

    // Mesh forward hop: a two-worker mesh cluster where every invocation
    // chains one `forward` (leader → w0 → w1, the final hop relaying its
    // reply straight back to the leader's collector) — the per-hop price
    // of re-injecting a frame over the worker mesh, against the plain
    // window-1 pipelined invoke row above.
    {
        use two_chains::coordinator::{Cluster, ClusterConfig, Target};
        use two_chains::ifunc::builtin::HopIfunc;
        let cluster = Cluster::launch(
            ClusterConfig::builder().workers(2).mesh(true).build().expect("config"),
            |_, ctx, _| {
                ctx.library_dir().install(Box::new(HopIfunc));
            },
        )
        .expect("cluster");
        cluster.leader.library_dir().install(Box::new(HopIfunc));
        let d = cluster.dispatcher();
        let h = d.register("hop").expect("register");
        let m = h
            .msg_create(&SourceArgs::bytes(HopIfunc::payload(&[1], &[0x5Au8; 64])))
            .expect("msg");
        let iters = if quick { 300 } else { 3000 };
        let t0 = Instant::now();
        for _ in 0..iters {
            assert!(d.invoke_one(Target::Worker(0), &m).expect("invoke").ok());
        }
        let ns = t0.elapsed().as_nanos() as f64 / iters as f64;
        let name = "forward hop (64B, mesh)".to_string();
        println!("{name:<44} {ns:>12.0} ns/op");
        t.rows.push(MicroRow { name, median_ns: ns, best_ns: ns });
        cluster.shutdown().expect("shutdown");
    }

    // Collective invocation: one `invoke_all` fan-out + merged wait per
    // iteration against a 4-worker pool — the per-round cost of a full
    // scatter-gather (inject once, every link posted before the flush
    // pass, replies collected per worker at the leader).
    {
        use two_chains::coordinator::{Cluster, ClusterConfig};
        let cluster = Cluster::launch(
            ClusterConfig::builder().workers(4).build().expect("config"),
            |_, ctx, _| {
                ctx.library_dir().install(Box::new(CounterIfunc::default()));
            },
        )
        .expect("cluster");
        cluster.leader.library_dir().install(Box::new(CounterIfunc::default()));
        let d = cluster.dispatcher();
        let h = d.register("counter").expect("register");
        let m = h.msg_create(&SourceArgs::bytes(vec![0u8; 64])).expect("msg");
        let iters = if quick { 100 } else { 1000 };
        let t0 = Instant::now();
        for _ in 0..iters {
            let merged = d.invoke_all(&m).expect("invoke_all").wait().expect("wait");
            assert!(merged.all_ok() && merged.len() == 4);
        }
        let ns = t0.elapsed().as_nanos() as f64 / iters as f64;
        let name = "invoke_all (4 workers, 64B)".to_string();
        println!("{name:<44} {ns:>12.0} ns/op");
        t.rows.push(MicroRow { name, median_ns: ns, best_ns: ns });
        cluster.shutdown().expect("shutdown");
    }

    // Big-record invoke_get: the reply streams as chunked frames (256 KiB
    // = 4 chunks, 1 MiB = 16 chunks through the 64-slot reply ring). The
    // `stream off` row is the old REPLY_INLINE_CAP behavior — the reply
    // overflows and ships NO payload, so its time is a floor, not a fair
    // rival: it measures what the old protocol charged for *failing* to
    // return the record.
    {
        use two_chains::coordinator::{
            Cluster, ClusterConfig, GetIfunc, InsertIfunc, Target, TransportKind,
        };
        for (name, bytes, stream, transport) in [
            ("invoke_get 256KiB record (streamed)", 256usize << 10, true, TransportKind::Ring),
            ("invoke_get 1MiB record (streamed)", 1usize << 20, true, TransportKind::Ring),
            (
                "invoke_get 1MiB record (streamed, shm)",
                1usize << 20,
                true,
                TransportKind::Shm,
            ),
            (
                "invoke_get 1MiB record (stream off: overflow, no payload)",
                1usize << 20,
                false,
                TransportKind::Ring,
            ),
        ] {
            let cluster = Cluster::launch(
                ClusterConfig::builder()
                    .workers(1)
                    .stream_replies(stream)
                    .transport(transport)
                    .build()
                    .expect("config"),
                |_, _, _| {},
            )
            .expect("cluster");
            cluster.leader.library_dir().install(Box::new(InsertIfunc));
            cluster.leader.library_dir().install(Box::new(GetIfunc));
            let d = cluster.dispatcher();
            let h_ins = d.register("insert").expect("register insert");
            let h_get = d.register("get").expect("register get");
            let record: Vec<f32> = (0..bytes / 4).map(|i| i as f32).collect();
            let key = 7u64;
            d.send(
                Target::Worker(0),
                &h_ins.msg_create(&InsertIfunc::args(key, &record)).expect("msg"),
            )
            .expect("insert");
            d.barrier().expect("barrier");
            let get = h_get.msg_create(&GetIfunc::args(key)).expect("msg");
            let iters = if quick { 20 } else { 200 };
            let t0 = Instant::now();
            for _ in 0..iters {
                let (reply, data) = d.fetch(Target::Worker(0), &get).expect("fetch");
                if stream {
                    assert!(reply.ok() && data.len() == bytes / 4);
                } else {
                    assert!(reply.overflowed() && data.is_empty());
                }
            }
            let ns = t0.elapsed().as_nanos() as f64 / iters as f64;
            println!("{name:<44} {ns:>12.0} ns/op");
            t.rows.push(MicroRow { name: name.to_string(), median_ns: ns, best_ns: ns });
            cluster.shutdown().expect("shutdown");
        }
    }

    // The concurrent serve front-end: 16 pipelined client sessions
    // pushing inserts through the cross-client coalescer on the default
    // ring transport — the per-request cost of the full serve path
    // (session window + per-worker queue + try_invoke_batch + reap).
    {
        use two_chains::coordinator::{Cluster, ClusterConfig, Frontend, FrontendConfig};
        use two_chains::util::Json;
        let cluster = Arc::new(
            Cluster::launch(
                ClusterConfig::builder().workers(4).build().expect("config"),
                |_, _, _| {},
            )
            .expect("cluster"),
        );
        let frontend = Arc::new(
            Frontend::launch(
                cluster.clone(),
                FrontendConfig { queue_high_water: 1 << 20, ..FrontendConfig::default() },
            )
            .expect("frontend"),
        );
        let clients = 16usize;
        let ops = if quick { 50 } else { 500 };
        let t0 = Instant::now();
        let threads: Vec<_> = (0..clients)
            .map(|c| {
                let fe = frontend.clone();
                std::thread::spawn(move || {
                    let (session, responses) = fe.session().expect("session");
                    let mut sent = 0usize;
                    let mut got = 0usize;
                    for i in 0..ops {
                        while sent - got >= 8 {
                            let r = responses
                                .recv_timeout(std::time::Duration::from_secs(60))
                                .expect("reply");
                            assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
                            got += 1;
                        }
                        let key = (c * ops + i) as u64;
                        session.submit(&format!(
                            "{{\"cmd\":\"insert\",\"key\":{key},\"data\":[1.0,2.0]}}"
                        ));
                        sent += 1;
                    }
                    while got < sent {
                        let r = responses
                            .recv_timeout(std::time::Duration::from_secs(60))
                            .expect("reply");
                        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
                        got += 1;
                    }
                })
            })
            .collect();
        for th in threads {
            th.join().expect("client thread");
        }
        let ns = t0.elapsed().as_nanos() as f64 / (clients * ops) as f64;
        let name = "serve insert (coalesced, 16 clients)".to_string();
        println!("{name:<44} {ns:>12.0} ns/op");
        t.rows.push(MicroRow { name, median_ns: ns, best_ns: ns });
        Arc::try_unwrap(frontend).ok().expect("sessions closed").shutdown();
        Arc::try_unwrap(cluster).ok().expect("frontend gone").shutdown().expect("shutdown");
    }

    if let Some(path) = json_path() {
        let report = micro_json(&t.rows);
        std::fs::write(&path, &report).expect("write micro JSON report");
        eprintln!("wrote {} rows to {}", t.rows.len(), path.display());
    }
    println!("\n(see EXPERIMENTS.md §Perf for the before/after log)");
}
