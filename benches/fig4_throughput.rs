//! Fig. 4 — message throughput, ifunc vs UCX AM (paper §4.3).
//!
//! ifunc protocol: fill the target ring with frames, flush, wait for the
//! target's consumed-all notification, repeat. AM protocol: stream sends
//! and flush once (§4.1).
//!
//! Paper shape to reproduce: ifunc rate ~81% lower at 1 B; AM protocol
//! *steps* (short → bcopy → rendezvous) with a sharp falloff at the
//! 1 KB → 2 KB rendezvous switch, where ifuncs take over (spiking, then
//! settling to a persistent win at large payloads).
//!
//! Run: `cargo bench --bench fig4_throughput` (QUICK=1 for a smoke run).

use two_chains::bench::harness::{BenchConfig, BenchPair};
use two_chains::bench::{report, throughput};

fn main() {
    let quick = std::env::var("QUICK").is_ok_and(|v| !v.is_empty() && v != "0");
    let cfg = if quick {
        BenchConfig { sizes: vec![64, 4096, 65536], msgs_per_size: 200, ..BenchConfig::quick() }
    } else {
        BenchConfig::default()
    };
    eprintln!(
        "fig4: sweeping {} sizes, {} msgs each (wire model {})",
        cfg.sizes.len(),
        cfg.msgs_per_size,
        if cfg.wire.enabled { "on: CX-6" } else { "off" }
    );

    let mut series = Vec::new();
    for &size in &cfg.sizes {
        // Cap total moved bytes so the 1 MB point stays fast.
        let msgs = cfg.msgs_per_size.min((256 << 20) / size.max(1)).max(50);
        let pair = BenchPair::new(cfg.clone()).expect("bench pair");
        let ifunc = throughput::ifunc_throughput(&pair, size, msgs).expect("ifunc tput");
        let am = throughput::am_throughput(&pair, size, msgs).expect("am tput");
        series.push(report::SeriesPoint { size, ifunc, am });
        eprint!(".");
    }
    eprintln!();
    report::print_series("Fig. 4 — message throughput, ifunc vs UCX AM", "msg/s", &series, false);
    println!("{}", report::series_json("fig4", &series));
}
